//! Node-scoped **device arbitration**: many co-located hetero models
//! (tenants) share one simulated GPU, one FPGA and one link, acquiring
//! each device per-op through a fair grant queue.
//!
//! Before this module, every hetero pipeline owned *private* device
//! lanes ([`crate::runtime::device`]), so cross-model interference — the
//! multi-tenant regime where the paper's hybrid-beats-GPU-only claim is
//! actually interesting — was invisible. A node now owns one
//! [`DeviceSet`]; each pipeline registers as a tenant
//! ([`DeviceSet::register_tenant`]) and its lanes acquire the shared
//! device for exactly the duration of each hold.
//!
//! Following DESIGN.md §11, the decision logic is a **pure step core**:
//! [`ArbiterCore`] maps [`ArbiterEvent`]s to [`ArbiterEffect`]s with no
//! clocks, threads or channels, so the schedule explorer
//! ([`crate::check`]) can enumerate grant interleavings
//! (`check::scenarios::arbiter_grants_exactly_once`). The
//! [`DeviceArbiter`] shell wraps the core in a `Mutex` + `Condvar` and
//! turns `Granted` effects into wake-ups of the blocked acquirers.
//!
//! Grant ordering contract (the core's invariants, model-checked and
//! property-tested):
//! - each device serves **one holder at a time** (capacity 1 — one GPU,
//!   one FPGA, one link per node);
//! - a ticket is granted **at most once**, and never after it was
//!   cancelled;
//! - among waiting requests, **higher priority wins**; within a
//!   priority class, grants are FIFO in arrival order (no later
//!   arrival overtakes an earlier same-priority one);
//! - [`ArbiterEvent::Release`] always returns capacity: the head
//!   waiter (if any) is granted in the *same* step;
//! - [`ArbiterEvent::Retire`] cancels the tenant's queued requests
//!   (each acknowledged with a `Cancelled` effect — nothing is lost
//!   silently) and never disturbs other tenants' grants.

use crate::link::contention::BusModel;
use crate::metrics::device::{ArbiterCounters, NodeDeviceMetrics};
use std::collections::BTreeSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// Which shared node device a request targets.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum DeviceId {
    /// The GPU lane (Jetson TX2 side).
    Gpu,
    /// The FPGA lane (Cyclone 10 GX DHM side).
    Fpga,
    /// The PCIe link channel between the boards.
    Link,
}

impl DeviceId {
    /// Every device, in a fixed order (also the internal line index).
    pub const ALL: [DeviceId; 3] = [DeviceId::Gpu, DeviceId::Fpga, DeviceId::Link];

    /// Stable index into per-device arrays.
    pub fn index(self) -> usize {
        match self {
            DeviceId::Gpu => 0,
            DeviceId::Fpga => 1,
            DeviceId::Link => 2,
        }
    }

    /// Lane name, as it appears in summaries.
    pub fn name(self) -> &'static str {
        match self {
            DeviceId::Gpu => "gpu",
            DeviceId::Fpga => "fpga",
            DeviceId::Link => "link",
        }
    }
}

/// A registered co-located model (one hetero pipeline = one tenant).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TenantId(
    /// Node-unique tenant number.
    pub u64,
);

/// One acquisition request's identity, unique for the node's lifetime.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Ticket(
    /// Node-unique ticket number.
    pub u64,
);

/// Everything the arbitration core reacts to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArbiterEvent {
    /// A tenant asks for a device. Granted immediately when the device
    /// is free, queued otherwise.
    Request {
        /// The request's identity (shell-issued, never reused).
        ticket: Ticket,
        /// The asking tenant.
        tenant: TenantId,
        /// The device asked for.
        device: DeviceId,
        /// Grant priority: higher wins; ties break FIFO by arrival.
        priority: u8,
    },
    /// The holder of `ticket` is done; capacity returns and the head
    /// waiter (if any) is granted in this same step. Releasing a ticket
    /// that is not currently holding is a no-op (idempotent).
    Release {
        /// The ticket being released.
        ticket: Ticket,
    },
    /// The tenant is going away: cancel its *queued* requests (each
    /// acknowledged with [`ArbiterEffect::Cancelled`]). An in-service
    /// hold is left to finish — its `Release` still returns capacity.
    Retire {
        /// The departing tenant.
        tenant: TenantId,
    },
}

/// Everything the core can tell its shell.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArbiterEffect {
    /// `ticket` now holds `device`; wake its acquirer.
    Granted {
        /// The granted ticket.
        ticket: Ticket,
        /// The tenant that owns the ticket.
        tenant: TenantId,
        /// The device granted.
        device: DeviceId,
    },
    /// `ticket` will never be granted (its tenant retired mid-wait);
    /// wake its acquirer with the bad news.
    Cancelled {
        /// The cancelled ticket.
        ticket: Ticket,
        /// The tenant that owned the ticket.
        tenant: TenantId,
        /// The device it was waiting for.
        device: DeviceId,
    },
}

/// One queued request (internal line entry).
#[derive(Debug, Clone, Copy)]
struct Waiting {
    ticket: Ticket,
    tenant: TenantId,
    priority: u8,
    /// Arrival order stamp: the FIFO tiebreak within a priority class.
    seq: u64,
}

/// One device's grant line: the current holder plus the wait queue.
#[derive(Debug, Default)]
struct Line {
    holder: Option<(Ticket, TenantId)>,
    queue: Vec<Waiting>,
}

impl Line {
    /// Index of the next grant: max priority, then min arrival seq.
    fn head(&self) -> Option<usize> {
        let mut best: Option<usize> = None;
        for (i, w) in self.queue.iter().enumerate() {
            best = match best {
                None => Some(i),
                Some(b) => {
                    let cur = &self.queue[b];
                    if w.priority > cur.priority
                        || (w.priority == cur.priority && w.seq < cur.seq)
                    {
                        Some(i)
                    } else {
                        Some(b)
                    }
                }
            };
        }
        best
    }
}

/// The pure arbitration state machine: `ArbiterEvent -> Vec<ArbiterEffect>`.
///
/// No clocks, no threads, no I/O — drive it from the [`DeviceArbiter`]
/// shell in production or from the schedule explorer in tests.
#[derive(Debug, Default)]
pub struct ArbiterCore {
    lines: [Line; 3],
    next_seq: u64,
}

impl ArbiterCore {
    /// Fresh core: all devices free, all queues empty.
    pub fn new() -> Self {
        Self::default()
    }

    /// Apply one event; returns the effects the shell must act on.
    pub fn step(&mut self, event: ArbiterEvent) -> Vec<ArbiterEffect> {
        match event {
            ArbiterEvent::Request { ticket, tenant, device, priority } => {
                let seq = self.next_seq;
                self.next_seq += 1;
                let line = &mut self.lines[device.index()];
                debug_assert!(
                    line.queue.iter().all(|w| w.ticket != ticket)
                        && line.holder.map(|(t, _)| t) != Some(ticket),
                    "ticket reuse"
                );
                if line.holder.is_none() && line.queue.is_empty() {
                    line.holder = Some((ticket, tenant));
                    vec![ArbiterEffect::Granted { ticket, tenant, device }]
                } else {
                    line.queue.push(Waiting { ticket, tenant, priority, seq });
                    Vec::new()
                }
            }
            ArbiterEvent::Release { ticket } => {
                for (i, line) in self.lines.iter_mut().enumerate() {
                    if line.holder.map(|(t, _)| t) == Some(ticket) {
                        line.holder = None;
                        if let Some(head) = line.head() {
                            let w = line.queue.remove(head);
                            line.holder = Some((w.ticket, w.tenant));
                            return vec![ArbiterEffect::Granted {
                                ticket: w.ticket,
                                tenant: w.tenant,
                                device: DeviceId::ALL[i],
                            }];
                        }
                        return Vec::new();
                    }
                }
                Vec::new()
            }
            ArbiterEvent::Retire { tenant } => {
                let mut fx = Vec::new();
                for (i, line) in self.lines.iter_mut().enumerate() {
                    let device = DeviceId::ALL[i];
                    line.queue.retain(|w| {
                        if w.tenant == tenant {
                            fx.push(ArbiterEffect::Cancelled {
                                ticket: w.ticket,
                                tenant,
                                device,
                            });
                            false
                        } else {
                            true
                        }
                    });
                }
                fx
            }
        }
    }

    /// The current holder of `device`, if any.
    pub fn holder(&self, device: DeviceId) -> Option<(Ticket, TenantId)> {
        self.lines[device.index()].holder
    }

    /// How many requests wait on `device`.
    pub fn queue_len(&self, device: DeviceId) -> usize {
        self.lines[device.index()].queue.len()
    }

    /// Waiting tickets on `device` in **grant order** (priority, then
    /// arrival) — what the fairness properties assert against.
    pub fn queued(&self, device: DeviceId) -> Vec<Ticket> {
        let line = &self.lines[device.index()];
        let mut v: Vec<Waiting> = line.queue.clone();
        v.sort_by(|a, b| b.priority.cmp(&a.priority).then(a.seq.cmp(&b.seq)));
        v.into_iter().map(|w| w.ticket).collect()
    }

    /// True when every device is free and every queue is empty.
    pub fn quiescent(&self) -> bool {
        self.lines.iter().all(|l| l.holder.is_none() && l.queue.is_empty())
    }
}

/// Shell state: the core plus the grant/cancel flags acquirers wait on.
#[derive(Debug, Default)]
struct ArbState {
    core: ArbiterCore,
    granted: BTreeSet<u64>,
    cancelled: BTreeSet<u64>,
}

impl ArbState {
    fn apply(&mut self, effects: Vec<ArbiterEffect>) {
        for fx in effects {
            match fx {
                ArbiterEffect::Granted { ticket, .. } => {
                    self.granted.insert(ticket.0);
                }
                ArbiterEffect::Cancelled { ticket, .. } => {
                    self.cancelled.insert(ticket.0);
                }
            }
        }
    }
}

/// The production shell around [`ArbiterCore`]: a `Mutex` + `Condvar`
/// that blocks acquirers until their ticket is granted (or cancelled by
/// a retire). All waiting is wall-clock-free of the core itself.
#[derive(Debug, Default)]
pub struct DeviceArbiter {
    state: Mutex<ArbState>,
    cv: Condvar,
    next_ticket: AtomicU64,
}

impl DeviceArbiter {
    /// Fresh arbiter: all devices free.
    pub fn new() -> Self {
        Self::default()
    }

    /// Block until `tenant` holds `device`; `None` if the tenant was
    /// retired while waiting. Returns the ticket now holding the device.
    fn acquire_blocking(&self, device: DeviceId, tenant: TenantId) -> Option<Ticket> {
        let ticket = Ticket(self.next_ticket.fetch_add(1, Ordering::Relaxed));
        let mut st = self.state.lock().expect("arbiter lock");
        let fx = st.core.step(ArbiterEvent::Request {
            ticket,
            tenant,
            device,
            priority: 0,
        });
        st.apply(fx);
        loop {
            if st.granted.remove(&ticket.0) {
                return Some(ticket);
            }
            if st.cancelled.remove(&ticket.0) {
                return None;
            }
            st = self.cv.wait(st).expect("arbiter lock");
        }
    }

    /// Return capacity for `ticket` and wake whoever is granted next.
    fn release(&self, ticket: Ticket) {
        let mut st = self.state.lock().expect("arbiter lock");
        let fx = st.core.step(ArbiterEvent::Release { ticket });
        st.apply(fx);
        drop(st);
        self.cv.notify_all();
    }

    /// Cancel `tenant`'s queued requests and wake the cancelled waiters.
    fn retire(&self, tenant: TenantId) {
        let mut st = self.state.lock().expect("arbiter lock");
        let fx = st.core.step(ArbiterEvent::Retire { tenant });
        st.apply(fx);
        drop(st);
        self.cv.notify_all();
    }
}

/// One node's shared devices: the arbiter, the cross-tenant counters
/// and the analytic bus model that prices link holds
/// ([`crate::link::contention::BusModel`] as the live seam).
#[derive(Debug, Default)]
pub struct DeviceSet {
    arbiter: DeviceArbiter,
    metrics: Arc<NodeDeviceMetrics>,
    bus: BusModel,
    next_tenant: AtomicU64,
}

impl DeviceSet {
    /// A fresh node: all devices free, default PCIe bus model.
    pub fn new() -> Self {
        Self::default()
    }

    /// Register one co-located model; drop the lease to retire it.
    pub fn register_tenant(self: &Arc<Self>) -> TenantLease {
        let tenant = TenantId(self.next_tenant.fetch_add(1, Ordering::Relaxed));
        TenantLease { set: Arc::clone(self), tenant }
    }

    /// The cross-tenant per-device counters.
    pub fn metrics(&self) -> &Arc<NodeDeviceMetrics> {
        &self.metrics
    }

    /// The analytic link model pricing shared-link holds.
    pub fn bus(&self) -> &BusModel {
        &self.bus
    }

    fn counters(&self, device: DeviceId) -> &ArbiterCounters {
        match device {
            DeviceId::Gpu => &self.metrics.gpu,
            DeviceId::Fpga => &self.metrics.fpga,
            DeviceId::Link => &self.metrics.link,
        }
    }
}

/// One tenant's handle on the shared [`DeviceSet`]. Lanes clone the
/// `Arc<TenantLease>`; when the last lane drops it, the tenant retires
/// (queued requests cancelled, nothing else disturbed).
#[derive(Debug)]
pub struct TenantLease {
    set: Arc<DeviceSet>,
    tenant: TenantId,
}

impl TenantLease {
    /// This tenant's id (stable for the lease's lifetime).
    pub fn tenant(&self) -> TenantId {
        self.tenant
    }

    /// The node's analytic link model.
    pub fn bus(&self) -> &BusModel {
        self.set.bus()
    }

    /// The node's cross-tenant counters for `device`.
    pub fn counters(&self, device: DeviceId) -> &ArbiterCounters {
        self.set.counters(device)
    }

    /// Block until this tenant holds `device`; the wait is recorded in
    /// the node counters. `None` only if the tenant retired mid-wait —
    /// impossible while the caller holds the lease, so device lanes
    /// `expect` it.
    pub fn acquire(&self, device: DeviceId) -> Option<DeviceGrant<'_>> {
        let t0 = Instant::now();
        let ticket = self.set.arbiter.acquire_blocking(device, self.tenant)?;
        self.set.counters(device).record_grant(t0.elapsed());
        Some(DeviceGrant { set: &self.set, ticket })
    }
}

impl Drop for TenantLease {
    fn drop(&mut self) {
        self.set.arbiter.retire(self.tenant);
    }
}

/// An exclusive hold on one shared device; releases on drop.
#[derive(Debug)]
pub struct DeviceGrant<'a> {
    set: &'a DeviceSet,
    ticket: Ticket,
}

impl Drop for DeviceGrant<'_> {
    fn drop(&mut self) {
        self.set.arbiter.release(self.ticket);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn req(core: &mut ArbiterCore, t: u64, ten: u64, dev: DeviceId) -> Vec<ArbiterEffect> {
        core.step(ArbiterEvent::Request {
            ticket: Ticket(t),
            tenant: TenantId(ten),
            device: dev,
            priority: 0,
        })
    }

    #[test]
    fn free_device_grants_immediately_and_fifo_after() {
        let mut core = ArbiterCore::new();
        let fx = req(&mut core, 0, 1, DeviceId::Gpu);
        assert_eq!(
            fx,
            vec![ArbiterEffect::Granted {
                ticket: Ticket(0),
                tenant: TenantId(1),
                device: DeviceId::Gpu
            }]
        );
        assert!(req(&mut core, 1, 2, DeviceId::Gpu).is_empty());
        assert!(req(&mut core, 2, 1, DeviceId::Gpu).is_empty());
        assert_eq!(core.queued(DeviceId::Gpu), vec![Ticket(1), Ticket(2)]);
        // release grants the earliest waiter, in the same step
        let fx = core.step(ArbiterEvent::Release { ticket: Ticket(0) });
        assert_eq!(
            fx,
            vec![ArbiterEffect::Granted {
                ticket: Ticket(1),
                tenant: TenantId(2),
                device: DeviceId::Gpu
            }]
        );
        assert_eq!(core.holder(DeviceId::Gpu), Some((Ticket(1), TenantId(2))));
    }

    #[test]
    fn higher_priority_overtakes_lower_but_not_same() {
        let mut core = ArbiterCore::new();
        req(&mut core, 0, 1, DeviceId::Fpga);
        core.step(ArbiterEvent::Request {
            ticket: Ticket(1),
            tenant: TenantId(1),
            device: DeviceId::Fpga,
            priority: 0,
        });
        core.step(ArbiterEvent::Request {
            ticket: Ticket(2),
            tenant: TenantId(2),
            device: DeviceId::Fpga,
            priority: 3,
        });
        assert_eq!(core.queued(DeviceId::Fpga), vec![Ticket(2), Ticket(1)]);
        let fx = core.step(ArbiterEvent::Release { ticket: Ticket(0) });
        assert!(matches!(fx[0], ArbiterEffect::Granted { ticket: Ticket(2), .. }));
    }

    #[test]
    fn retire_cancels_only_the_tenants_queued_requests() {
        let mut core = ArbiterCore::new();
        req(&mut core, 0, 1, DeviceId::Link); // tenant 1 holds
        req(&mut core, 1, 2, DeviceId::Link); // tenant 2 waits
        req(&mut core, 2, 1, DeviceId::Link); // tenant 1 waits
        let fx = core.step(ArbiterEvent::Retire { tenant: TenantId(1) });
        assert_eq!(
            fx,
            vec![ArbiterEffect::Cancelled {
                ticket: Ticket(2),
                tenant: TenantId(1),
                device: DeviceId::Link
            }]
        );
        // the hold survives retire; its release still grants tenant 2
        assert_eq!(core.holder(DeviceId::Link), Some((Ticket(0), TenantId(1))));
        let fx = core.step(ArbiterEvent::Release { ticket: Ticket(0) });
        assert!(matches!(fx[0], ArbiterEffect::Granted { ticket: Ticket(1), .. }));
        let fx = core.step(ArbiterEvent::Release { ticket: Ticket(1) });
        assert!(fx.is_empty());
        assert!(core.quiescent());
    }

    #[test]
    fn release_of_unknown_ticket_is_a_no_op() {
        let mut core = ArbiterCore::new();
        assert!(core.step(ArbiterEvent::Release { ticket: Ticket(99) }).is_empty());
        assert!(core.quiescent());
    }

    #[test]
    fn shell_serializes_two_tenants_and_counts_grants() {
        let set = Arc::new(DeviceSet::new());
        let mut joins = Vec::new();
        for _ in 0..2 {
            let lease = Arc::new(set.register_tenant());
            joins.push(thread::spawn(move || {
                for _ in 0..8 {
                    let grant = lease.acquire(DeviceId::Gpu).expect("lease alive");
                    // hold briefly so contention is real
                    std::hint::spin_loop();
                    drop(grant);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let m = set.metrics();
        assert_eq!(m.gpu.grants(), 16);
        assert_eq!(m.fpga.grants(), 0);
        assert_eq!(m.gpu.cancelled(), 0);
    }

    #[test]
    fn lease_drop_retires_cleanly_even_with_no_requests() {
        let set = Arc::new(DeviceSet::new());
        let lease = set.register_tenant();
        assert_eq!(lease.tenant(), TenantId(0));
        drop(lease);
        let lease2 = set.register_tenant();
        assert_eq!(lease2.tenant(), TenantId(1));
        let g = lease2.acquire(DeviceId::Link).expect("lease alive");
        drop(g);
        assert_eq!(set.metrics().link.grants(), 1);
    }
}
