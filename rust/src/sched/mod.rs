//! Event-timeline evaluation of partition plans.
//!
//! Turns a [`ModulePlan`]/[`ModelPlan`] into a concrete timeline: sequential
//! steps advance the clock, [`Step::Parallel`] branches race and join at the
//! max (the paper's §V-B latency hiding: "if the latency of the FPGA and the
//! communication is less than the GPU latency ... the max function ... will
//! be dominated by the GPU-side latency").
//!
//! Energy accounting (DESIGN.md §6): every step carries its active energy;
//! on top of that the evaluator charges
//! - GPU idle power whenever the GPU waits (e.g. during a sequential FPGA
//!   round trip — the Jetson does not power-gate between kernels), and
//! - FPGA static power whenever the FPGA is present but idle (heterogeneous
//!   plans pay for the second board; the GPU-only baseline does not).

pub mod pipeline;
pub mod trace;

use crate::dhm::CYCLONE10_GX220;
use crate::gpu::JETSON_TX2;
use crate::metrics::Cost;
use crate::partition::{ModelPlan, ModulePlan, Resource, Step};

/// Idle-power parameters charged by the evaluator.
#[derive(Debug, Clone, Copy)]
pub struct IdleParams {
    pub gpu_idle_w: f64,
    pub fpga_static_w: f64,
}

impl Default for IdleParams {
    fn default() -> Self {
        Self { gpu_idle_w: JETSON_TX2.p_idle, fpga_static_w: CYCLONE10_GX220.p_static }
    }
}

impl IdleParams {
    /// The paper's §V-A methodology: each task's energy is measured in
    /// isolation (TX2 power monitor per CUDA task, Quartus PE per DHM
    /// design) and composed — so no device is billed while it waits for
    /// the other. The physical `default()` parameters bill waiting devices
    /// and are used for deployment planning; the difference is the
    /// idle-billing ablation bench.
    pub fn paper() -> Self {
        Self { gpu_idle_w: 0.0, fpga_static_w: 0.0 }
    }
}

/// One step resolved onto the timeline.
#[derive(Debug, Clone)]
pub struct StepTiming {
    pub label: String,
    pub resource: Resource,
    pub start: f64,
    pub end: f64,
    pub joules: f64,
}

/// Evaluation of one module plan.
#[derive(Debug, Clone, Default)]
pub struct Evaluation {
    /// Total latency + energy including idle charges.
    pub total: Cost,
    /// Busy time per engine.
    pub gpu_busy: f64,
    pub fpga_busy: f64,
    pub link_busy: f64,
    /// Whether the FPGA board is in the loop (drives static-idle charging).
    pub uses_fpga: bool,
    pub timeline: Vec<StepTiming>,
}

fn walk(steps: &[Step], t0: f64, ev: &mut Evaluation) -> f64 {
    let mut t = t0;
    for s in steps {
        match s {
            Step::Parallel { gpu, fpga } => {
                let g_end = walk(gpu, t, ev);
                let f_end = walk(fpga, t, ev);
                t = g_end.max(f_end);
            }
            _ => {
                let (label, cost, res) = match s {
                    Step::Gpu { label, cost, .. } => (label, cost, Resource::Gpu),
                    Step::GpuData { label, cost } => (label, cost, Resource::Gpu),
                    Step::Fpga { label, cost, .. } => (label, cost, Resource::Fpga),
                    Step::Transfer { label, cost, .. } => (label, cost, Resource::Link),
                    Step::Parallel { .. } => unreachable!(),
                };
                let end = t + cost.seconds;
                ev.timeline.push(StepTiming {
                    label: label.clone(),
                    resource: res,
                    start: t,
                    end,
                    joules: cost.joules,
                });
                match res {
                    Resource::Gpu => ev.gpu_busy += cost.seconds,
                    Resource::Fpga => ev.fpga_busy += cost.seconds,
                    Resource::Link => ev.link_busy += cost.seconds,
                }
                ev.total.joules += cost.joules;
                t = end;
            }
        }
    }
    t
}

/// Timeline-free walk: (end time, busy[gpu,fpga,link], joules). The perf
/// fast path for planner acceptance loops, which only need totals — no
/// per-step allocation.
fn walk_cost(steps: &[Step], t0: f64, busy: &mut [f64; 3], joules: &mut f64) -> f64 {
    let mut t = t0;
    for s in steps {
        match s {
            Step::Parallel { gpu, fpga } => {
                let g_end = walk_cost(gpu, t, busy, joules);
                let f_end = walk_cost(fpga, t, busy, joules);
                t = g_end.max(f_end);
            }
            _ => {
                let (cost, bi) = match s {
                    Step::Gpu { cost, .. } | Step::GpuData { cost, .. } => (cost, 0),
                    Step::Fpga { cost, .. } => (cost, 1),
                    Step::Transfer { cost, .. } => (cost, 2),
                    Step::Parallel { .. } => unreachable!(),
                };
                busy[bi] += cost.seconds;
                *joules += cost.joules;
                t += cost.seconds;
            }
        }
    }
    t
}

/// Total cost of a module plan without building the timeline (identical
/// result to `evaluate_with(plan, idle).total`, several times faster).
pub fn evaluate_cost(plan: &ModulePlan, idle: IdleParams) -> Cost {
    let mut busy = [0.0f64; 3];
    let mut joules = 0.0f64;
    let makespan = walk_cost(&plan.steps, 0.0, &mut busy, &mut joules);
    joules += idle.gpu_idle_w * (makespan - busy[0]).max(0.0);
    if plan.uses_fpga {
        joules += idle.fpga_static_w * (makespan - busy[1]).max(0.0);
    }
    Cost::new(makespan, joules)
}

/// Evaluate a module plan starting at t = 0 with the given idle parameters.
pub fn evaluate_with(plan: &ModulePlan, idle: IdleParams) -> Evaluation {
    let mut ev = Evaluation { uses_fpga: plan.uses_fpga, ..Default::default() };
    let makespan = walk(&plan.steps, 0.0, &mut ev);
    ev.total.seconds = makespan;
    // idle charges
    ev.total.joules += idle.gpu_idle_w * (makespan - ev.gpu_busy).max(0.0);
    if plan.uses_fpga {
        ev.total.joules += idle.fpga_static_w * (makespan - ev.fpga_busy).max(0.0);
    }
    ev
}

/// Evaluate with default (paper-board) idle parameters.
pub fn evaluate(plan: &ModulePlan) -> Evaluation {
    evaluate_with(plan, IdleParams::default())
}

/// Whole-model evaluation: modules execute back-to-back.
#[derive(Debug, Clone, Default)]
pub struct ModelEvaluation {
    pub total: Cost,
    pub per_module: Vec<(String, Cost)>,
    pub gpu_busy: f64,
    pub fpga_busy: f64,
    pub link_busy: f64,
}

/// Evaluate a model plan with the given idle parameters.
///
/// Idle charging follows the paper's measurement methodology (§V-A):
/// each device's energy is integrated over *its own activity windows* —
/// the TX2 power monitor and Quartus PE report per-task energy, so a
/// module's cost includes GPU idle while that module waits on the FPGA,
/// and FPGA static while that module streams, but the FPGA is NOT billed
/// against modules that never touch it. For the pessimistic
/// whole-run-board-power view, see [`evaluate_model_strict`] (ablation).
pub fn evaluate_model_with(plan: &ModelPlan, idle: IdleParams) -> ModelEvaluation {
    let mut out = ModelEvaluation::default();
    for m in &plan.modules {
        let ev = evaluate_with(m, idle);
        out.gpu_busy += ev.gpu_busy;
        out.fpga_busy += ev.fpga_busy;
        out.link_busy += ev.link_busy;
        out.total.seconds += ev.total.seconds;
        out.total.joules += ev.total.joules;
        out.per_module.push((m.module_name.clone(), ev.total));
    }
    out
}

/// Evaluate a model plan with default idle parameters.
pub fn evaluate_model(plan: &ModelPlan) -> ModelEvaluation {
    evaluate_model_with(plan, IdleParams::default())
}

/// Pessimistic ablation: the FPGA board's static power is billed across
/// the WHOLE inference makespan whenever any module uses it (the board
/// cannot be hot-unplugged between modules). The paper's methodology does
/// not do this; the ablation bench quantifies how much of the headline
/// gain survives it.
pub fn evaluate_model_strict(plan: &ModelPlan, idle: IdleParams) -> ModelEvaluation {
    let mut out = evaluate_model_with(plan, idle);
    if plan.uses_fpga() {
        // add static for every module span where the FPGA sat fully idle
        for (m, (_, cost)) in plan.modules.iter().zip(out.per_module.iter_mut()) {
            if !m.uses_fpga {
                let add = idle.fpga_static_w * cost.seconds;
                cost.joules += add;
                out.total.joules += add;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{models, TensorShape};
    use crate::partition::{Planner, Strategy};

    fn planner() -> Planner {
        Planner::default()
    }

    #[test]
    fn sequential_steps_add_latency() {
        let m = models::fire("f", TensorShape::new(54, 54, 96), 16, 64, 64);
        let p = planner().plan_gpu_only(&m);
        let ev = evaluate(&p);
        let sum: f64 = ev.timeline.iter().map(|t| t.end - t.start).sum();
        assert!((ev.total.seconds - sum).abs() < 1e-12, "gpu-only is fully serial");
        assert!((ev.gpu_busy - sum).abs() < 1e-12);
    }

    #[test]
    fn parallel_branch_latency_is_hidden() {
        // Fire GConv split: the FPGA branch must overlap the GPU branch
        let m = models::fire("f", TensorShape::new(54, 54, 96), 16, 64, 64);
        let plan = planner().plan_gconv_split(&m).unwrap();
        let ev = evaluate(&plan);
        let serial: f64 = ev.timeline.iter().map(|t| t.end - t.start).sum();
        assert!(
            ev.total.seconds < serial - 1e-6,
            "parallel plan must beat its own serialization: {} vs {}",
            ev.total.seconds,
            serial
        );
    }

    #[test]
    fn timeline_events_overlap_only_across_resources() {
        let m = models::shuffle_reduce("r", TensorShape::new(55, 55, 24), 48);
        let plan = planner().plan_fused(&m).unwrap();
        let ev = evaluate(&plan);
        for a in &ev.timeline {
            for b in &ev.timeline {
                if std::ptr::eq(a, b) || a.resource != b.resource {
                    continue;
                }
                let overlap = a.start.max(b.start) < a.end.min(b.end) - 1e-15;
                assert!(!overlap, "same-resource overlap: {a:?} vs {b:?}");
            }
        }
    }

    #[test]
    fn gpu_idle_energy_charged_in_sequential_offload() {
        // DwSplit: GPU waits during xfer+fpga+xfer; idle energy must appear
        let m = models::bottleneck("bn", TensorShape::new(28, 28, 16), 16, 6, 1);
        let plan = planner().plan_dw_split(&m).unwrap();
        let ev = evaluate(&plan);
        let step_energy: f64 = ev.timeline.iter().map(|t| t.joules).sum();
        assert!(ev.total.joules > step_energy, "idle charges missing");
    }

    #[test]
    fn gpu_only_has_no_idle_charge() {
        let m = models::bottleneck("bn", TensorShape::new(28, 28, 16), 16, 6, 1);
        let plan = planner().plan_gpu_only(&m);
        let ev = evaluate(&plan);
        let step_energy: f64 = ev.timeline.iter().map(|t| t.joules).sum();
        assert!((ev.total.joules - step_energy).abs() < 1e-12);
    }

    #[test]
    fn model_eval_sums_modules() {
        let g = models::squeezenet(224);
        let plan = planner().plan_model(&g, Strategy::GpuOnly);
        let ev = evaluate_model(&plan);
        assert_eq!(ev.per_module.len(), g.modules.len());
        let span_sum: f64 = ev.per_module.iter().map(|(_, c)| c.seconds).sum();
        assert!((ev.total.seconds - span_sum).abs() < 1e-9);
    }

    #[test]
    fn hetero_model_beats_gpu_only_in_energy() {
        // the paper's headline: hetero wins energy on all three nets
        let p = planner();
        for g in models::all_models() {
            let base = evaluate_model(&p.plan_model(&g, Strategy::GpuOnly));
            let het = evaluate_model(&p.plan_model(&g, Strategy::Auto));
            assert!(
                het.total.joules < base.total.joules,
                "{}: hetero {} J !< gpu {} J",
                g.name,
                het.total.joules,
                base.total.joules
            );
        }
    }

    #[test]
    fn makespan_at_least_busiest_resource() {
        let m = models::shuffle_reduce("r", TensorShape::new(55, 55, 24), 48);
        let plan = planner().plan_fused(&m).unwrap();
        let ev = evaluate(&plan);
        assert!(ev.total.seconds >= ev.gpu_busy - 1e-12);
        assert!(ev.total.seconds >= ev.fpga_busy - 1e-12);
        assert!(ev.total.seconds >= ev.link_busy - 1e-12);
    }
}
