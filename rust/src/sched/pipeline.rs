//! Batch-pipelined execution — the throughput face of the platform.
//!
//! The paper's DHM substrate is "throughput-optimised [and] pipe-lined"
//! (§I): once a module's layers are resident, the FPGA can accept image
//! i+1 while the GPU works on image i. This module models steady-state
//! *throughput* of a heterogeneous plan over a batch of images, as opposed
//! to the single-image *latency* that [`super::evaluate`] reports:
//!
//! - every module plan is reduced to its per-resource service demand
//!   (GPU / FPGA / PCIe busy seconds),
//! - the pipeline bottleneck is the resource with the largest total
//!   demand per image,
//! - steady-state throughput = 1 / bottleneck, and batch makespan =
//!   fill latency + (n-1) * bottleneck.
//!
//! Energy per image in steady state adds each resource's active energy
//! plus idle energy of the non-bottleneck resources while they wait.

use crate::metrics::Cost;
use crate::partition::{ModelPlan, Resource};
use crate::sched::{evaluate_model_with, IdleParams};

/// Per-resource service demand of one image through a plan.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ServiceDemand {
    pub gpu: f64,
    pub fpga: f64,
    pub link: f64,
    /// Active energy for one image (no idle).
    pub joules: f64,
    /// Single-image latency (fill time of the pipeline).
    pub fill: f64,
}

impl ServiceDemand {
    /// The stage that bounds steady-state throughput.
    pub fn bottleneck(&self) -> (Resource, f64) {
        let mut best = (Resource::Gpu, self.gpu);
        if self.fpga > best.1 {
            best = (Resource::Fpga, self.fpga);
        }
        if self.link > best.1 {
            best = (Resource::Link, self.link);
        }
        best
    }
}

/// Reduce a model plan to its per-image service demand.
pub fn service_demand(plan: &ModelPlan) -> ServiceDemand {
    // reuse the single-image evaluation for busy times + active energy
    let ev = evaluate_model_with(plan, IdleParams { gpu_idle_w: 0.0, fpga_static_w: 0.0 });
    ServiceDemand {
        gpu: ev.gpu_busy,
        fpga: ev.fpga_busy,
        link: ev.link_busy,
        joules: ev.total.joules,
        fill: ev.total.seconds,
    }
}

/// Steady-state pipelined execution of `n` images.
#[derive(Debug, Clone, Copy)]
pub struct PipelineRun {
    pub n: usize,
    /// Total wall time for the batch.
    pub makespan: f64,
    /// Images per second in steady state.
    pub throughput: f64,
    /// Total energy for the batch (active + idle of waiting resources).
    pub joules: f64,
    /// The limiting resource.
    pub bottleneck: Resource,
}

impl PipelineRun {
    pub fn cost(&self) -> Cost {
        Cost::new(self.makespan, self.joules)
    }

    /// Energy per image.
    pub fn joules_per_image(&self) -> f64 {
        self.joules / self.n.max(1) as f64
    }
}

/// Evaluate a plan under batch pipelining with the given idle parameters.
pub fn evaluate_pipeline(plan: &ModelPlan, n: usize, idle: IdleParams) -> PipelineRun {
    assert!(n >= 1, "empty batch");
    let d = service_demand(plan);
    let (bottleneck, period) = d.bottleneck();
    let makespan = d.fill + (n as f64 - 1.0) * period;
    // active energy for n images + idle while each non-bottleneck resource
    // waits out the steady-state slack
    let slack = |busy: f64| ((period - busy).max(0.0)) * (n as f64 - 1.0);
    let uses_fpga = plan.uses_fpga();
    let mut joules = d.joules * n as f64;
    joules += idle.gpu_idle_w * slack(d.gpu);
    if uses_fpga {
        joules += idle.fpga_static_w * slack(d.fpga);
    }
    PipelineRun {
        n,
        makespan,
        throughput: if n > 1 { (n as f64 - 1.0) / (makespan - d.fill).max(1e-12) } else { 1.0 / d.fill },
        joules,
        bottleneck,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::partition::{Planner, Strategy};

    fn planner() -> Planner {
        Planner::default()
    }

    #[test]
    fn single_image_matches_fill_latency() {
        let p = planner();
        let g = models::squeezenet(224);
        let plan = p.plan_model_paper(&g);
        let run = evaluate_pipeline(&plan, 1, IdleParams::paper());
        let d = service_demand(&plan);
        assert!((run.makespan - d.fill).abs() < 1e-12);
    }

    #[test]
    fn makespan_linear_in_batch() {
        let p = planner();
        let g = models::shufflenetv2_05(224);
        let plan = p.plan_model_paper(&g);
        let r8 = evaluate_pipeline(&plan, 8, IdleParams::paper());
        let r16 = evaluate_pipeline(&plan, 16, IdleParams::paper());
        let d = service_demand(&plan);
        let (_, period) = d.bottleneck();
        assert!((r16.makespan - r8.makespan - 8.0 * period).abs() < 1e-9);
    }

    #[test]
    fn pipelined_throughput_beats_sequential() {
        // steady-state rate must beat 1/latency whenever work is split
        // across more than one resource
        let p = planner();
        let g = models::shufflenetv2_05(224);
        let plan = p.plan_model_paper(&g);
        let run = evaluate_pipeline(&plan, 64, IdleParams::paper());
        let d = service_demand(&plan);
        assert!(
            run.throughput > 1.0 / d.fill * 1.01,
            "pipelining gained nothing: {} vs {}",
            run.throughput,
            1.0 / d.fill
        );
    }

    #[test]
    fn gpu_only_bottleneck_is_gpu() {
        let p = planner();
        let g = models::squeezenet(224);
        let plan = p.plan_model(&g, Strategy::GpuOnly);
        let run = evaluate_pipeline(&plan, 8, IdleParams::paper());
        assert_eq!(run.bottleneck, Resource::Gpu);
        // gpu-only pipelining cannot beat the serial rate (one resource)
        let d = service_demand(&plan);
        assert!(run.throughput <= 1.0 / d.gpu + 1e-9);
    }

    #[test]
    fn hetero_pipeline_throughput_beats_gpu_only() {
        // the throughput version of the paper's headline
        let p = planner();
        for g in models::all_models() {
            let base = evaluate_pipeline(&p.plan_model(&g, Strategy::GpuOnly), 32, IdleParams::paper());
            let het = evaluate_pipeline(&p.plan_model_paper(&g), 32, IdleParams::paper());
            assert!(
                het.throughput > base.throughput,
                "{}: {} !> {}",
                g.name,
                het.throughput,
                base.throughput
            );
        }
    }

    #[test]
    fn energy_per_image_approaches_active_energy() {
        let p = planner();
        let g = models::squeezenet(224);
        let plan = p.plan_model_paper(&g);
        let run = evaluate_pipeline(&plan, 256, IdleParams::paper());
        let d = service_demand(&plan);
        let per = run.joules_per_image();
        assert!((per - d.joules).abs() / d.joules < 0.05, "{per} vs {}", d.joules);
    }

    #[test]
    fn idle_billing_raises_pipeline_energy() {
        let p = planner();
        let g = models::mobilenetv2_05(224);
        let plan = p.plan_model_paper(&g);
        let free = evaluate_pipeline(&plan, 16, IdleParams::paper());
        let paid = evaluate_pipeline(&plan, 16, IdleParams::default());
        assert!(paid.joules > free.joules);
        assert!((paid.makespan - free.makespan).abs() < 1e-12, "billing must not change time");
    }
}
