//! Chrome trace-event emitter for schedule timelines.
//!
//! Converts an evaluated plan into the Trace Event Format consumed by
//! `chrome://tracing` / Perfetto: one "thread" per engine (GPU / FPGA /
//! PCIe), one complete event per step. This is the debugging view of the
//! paper's Fig 2 schedules — you can *see* the GConv branches overlap and
//! the DwSplit round trip serialize.
//!
//! `hetero-dnn trace <model> --out trace.json` writes it from the CLI.

use crate::partition::{ModelPlan, Resource};
use crate::sched::{evaluate_with, IdleParams, StepTiming};

fn tid(r: Resource) -> u32 {
    match r {
        Resource::Gpu => 1,
        Resource::Fpga => 2,
        Resource::Link => 3,
    }
}

fn escape(s: &str) -> String {
    s.chars()
        .flat_map(|c| match c {
            '"' => "\\\"".chars().collect::<Vec<_>>(),
            '\\' => "\\\\".chars().collect(),
            c if (c as u32) < 0x20 => format!("\\u{:04x}", c as u32).chars().collect(),
            c => vec![c],
        })
        .collect()
}

fn push_event(out: &mut String, t: &StepTiming, t_base: f64, first: &mut bool) {
    if !*first {
        out.push(',');
    }
    *first = false;
    // times in microseconds per the trace spec
    out.push_str(&format!(
        "{{\"name\":\"{}\",\"cat\":\"{:?}\",\"ph\":\"X\",\"ts\":{:.3},\"dur\":{:.3},\"pid\":1,\"tid\":{},\"args\":{{\"joules\":{:.6e}}}}}",
        escape(&t.label),
        t.resource,
        (t_base + t.start) * 1e6,
        (t.end - t.start) * 1e6,
        tid(t.resource),
        t.joules
    ));
}

/// Render a whole-model plan as a Chrome trace JSON string.
pub fn model_trace_json(plan: &ModelPlan, idle: IdleParams) -> String {
    let mut out = String::from("{\"traceEvents\":[");
    let mut first = true;
    // thread names
    for (name, id) in [("GPU (Jetson TX2)", 1), ("FPGA (Cyclone 10 GX)", 2), ("PCIe gen2 x4", 3)] {
        if !first {
            out.push(',');
        }
        first = false;
        out.push_str(&format!(
            "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":1,\"tid\":{id},\"args\":{{\"name\":\"{name}\"}}}}"
        ));
    }
    let mut t_base = 0.0;
    for m in &plan.modules {
        let ev = evaluate_with(m, idle);
        for t in &ev.timeline {
            push_event(&mut out, t, t_base, &mut first);
        }
        t_base += ev.total.seconds;
    }
    out.push_str("]}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::json;
    use crate::graph::models;
    use crate::partition::Planner;

    #[test]
    fn trace_is_valid_json_with_events() {
        let p = Planner::default();
        let g = models::shufflenetv2_05(224);
        let plan = p.plan_model_paper(&g);
        let text = model_trace_json(&plan, IdleParams::paper());
        let doc = json::parse(&text).expect("trace must parse as JSON");
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        // 3 metadata + at least one event per module
        assert!(events.len() > plan.modules.len() + 3, "{} events", events.len());
    }

    #[test]
    fn events_cover_all_three_engines() {
        let p = Planner::default();
        let g = models::shufflenetv2_05(224);
        let plan = p.plan_model_paper(&g);
        let text = model_trace_json(&plan, IdleParams::paper());
        let doc = json::parse(&text).unwrap();
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let mut tids = std::collections::BTreeSet::new();
        for e in events {
            if e.get("ph").and_then(json::Json::as_str) == Some("X") {
                tids.insert(e.get("tid").unwrap().as_usize().unwrap());
            }
        }
        assert_eq!(tids.into_iter().collect::<Vec<_>>(), vec![1, 2, 3]);
    }

    #[test]
    fn event_times_nonnegative_and_ordered_per_module() {
        let p = Planner::default();
        let g = models::squeezenet(224);
        let plan = p.plan_model_paper(&g);
        let text = model_trace_json(&plan, IdleParams::paper());
        let doc = json::parse(&text).unwrap();
        for e in doc.get("traceEvents").unwrap().as_arr().unwrap() {
            if e.get("ph").and_then(json::Json::as_str) == Some("X") {
                let ts = e.get("ts").unwrap().as_f64().unwrap();
                let dur = e.get("dur").unwrap().as_f64().unwrap();
                assert!(ts >= 0.0 && dur >= 0.0);
            }
        }
    }

    #[test]
    fn labels_are_escaped() {
        assert_eq!(escape("a\"b\\c"), "a\\\"b\\\\c");
        assert_eq!(escape("x\ny"), "x\\u000ay");
    }
}
