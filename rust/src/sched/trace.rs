//! Chrome trace-event emitter for schedule timelines.
//!
//! Converts an evaluated plan into the Trace Event Format consumed by
//! `chrome://tracing` / Perfetto: one "thread" per engine (GPU / FPGA /
//! PCIe), one complete event per step. This is the debugging view of the
//! paper's Fig 2 schedules — you can *see* the GConv branches overlap and
//! the DwSplit round trip serialize.
//!
//! `hetero-dnn trace <model> --out trace.json` writes it from the CLI.

use crate::partition::{ModelPlan, Resource};
use crate::sched::{evaluate_with, IdleParams, StepTiming};

/// The shared viewer track table: `(tid, thread name)` per device lane.
///
/// Both trace emitters use it — this module for the *predicted*
/// `ModelPlan` timeline and [`crate::obs`] for the *measured* flight
/// recorder — so the two exports land device work on identical tracks
/// (and identical `cat` strings, the `Resource` debug names) and load
/// side-by-side in one viewer.
pub fn device_track(r: Resource) -> (u32, &'static str) {
    match r {
        Resource::Gpu => (1, "GPU (Jetson TX2)"),
        Resource::Fpga => (2, "FPGA (Cyclone 10 GX)"),
        Resource::Link => (3, "PCIe gen2 x4"),
    }
}

fn tid(r: Resource) -> u32 {
    device_track(r).0
}

pub(crate) fn escape(s: &str) -> String {
    s.chars()
        .flat_map(|c| match c {
            '"' => "\\\"".chars().collect::<Vec<_>>(),
            '\\' => "\\\\".chars().collect(),
            c if (c as u32) < 0x20 => format!("\\u{:04x}", c as u32).chars().collect(),
            c => vec![c],
        })
        .collect()
}

fn push_event(out: &mut String, t: &StepTiming, t_base: f64, first: &mut bool) {
    if !*first {
        out.push(',');
    }
    *first = false;
    // times in microseconds per the trace spec
    out.push_str(&format!(
        "{{\"name\":\"{}\",\"cat\":\"{:?}\",\"ph\":\"X\",\"ts\":{:.3},\"dur\":{:.3},\"pid\":1,\"tid\":{},\"args\":{{\"joules\":{:.6e}}}}}",
        escape(&t.label),
        t.resource,
        (t_base + t.start) * 1e6,
        (t.end - t.start) * 1e6,
        tid(t.resource),
        t.joules
    ));
}

/// Render a whole-model plan as a Chrome trace JSON string.
pub fn model_trace_json(plan: &ModelPlan, idle: IdleParams) -> String {
    let mut out = String::from("{\"traceEvents\":[");
    let mut first = true;
    // process + thread name metadata ("M" phase), so the viewer labels
    // the tracks instead of showing bare pid/tid numbers
    out.push_str(
        "{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":1,\
         \"args\":{\"name\":\"predicted timeline (ModelPlan)\"}}",
    );
    first = false;
    for r in [Resource::Gpu, Resource::Fpga, Resource::Link] {
        let (id, name) = device_track(r);
        out.push_str(&format!(
            ",{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":1,\"tid\":{id},\"args\":{{\"name\":\"{name}\"}}}}"
        ));
    }
    let mut t_base = 0.0;
    for m in &plan.modules {
        let ev = evaluate_with(m, idle);
        for t in &ev.timeline {
            push_event(&mut out, t, t_base, &mut first);
        }
        t_base += ev.total.seconds;
    }
    out.push_str("]}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::json;
    use crate::graph::models;
    use crate::partition::Planner;

    #[test]
    fn trace_is_valid_json_with_events() {
        let p = Planner::default();
        let g = models::shufflenetv2_05(224);
        let plan = p.plan_model_paper(&g);
        let text = model_trace_json(&plan, IdleParams::paper());
        let doc = json::parse(&text).expect("trace must parse as JSON");
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        // 4 metadata (process + 3 threads) + at least one per module
        assert!(events.len() > plan.modules.len() + 4, "{} events", events.len());
    }

    /// Both emitters — this predicted-timeline module and the measured
    /// flight recorder — must share the viewer vocabulary: identical
    /// device tids and thread names (via [`device_track`]), identical
    /// `cat` strings on device events, and process/thread "M" metadata
    /// in both exports.
    #[test]
    fn predicted_and_measured_traces_share_the_track_vocabulary() {
        use crate::obs::{EventKind, Recorder, TraceId};

        let p = Planner::default();
        let g = models::shufflenetv2_05(224);
        let plan = p.plan_model_paper(&g);
        let predicted = model_trace_json(&plan, IdleParams::paper());

        let rec = Recorder::new(64);
        let caller = rec.register("caller");
        caller.emit(TraceId(1), EventKind::Admitted);
        for r in [Resource::Gpu, Resource::Fpga, Resource::Link] {
            let lane = rec.lane_obs(r);
            lane.acquire(Some(TraceId(1)));
            lane.release(Some(TraceId(1)), 0, 50);
        }
        caller.emit(TraceId(1), EventKind::ReplyWritten);
        let measured = rec.snapshot().chrome_trace_json();

        // (device thread map, device-event cat set, metadata names)
        fn vocab(
            text: &str,
        ) -> (
            std::collections::BTreeMap<usize, String>,
            std::collections::BTreeSet<String>,
            std::collections::BTreeSet<String>,
        ) {
            let doc = json::parse(text).expect("trace parses");
            let mut threads = std::collections::BTreeMap::new();
            let mut cats = std::collections::BTreeSet::new();
            let mut metas = std::collections::BTreeSet::new();
            for e in doc.get("traceEvents").unwrap().as_arr().unwrap() {
                let ph = e.get("ph").and_then(json::Json::as_str);
                let tid = e.get("tid").and_then(json::Json::as_usize);
                match (ph, tid) {
                    (Some("M"), tid) => {
                        let name = e.get("name").unwrap().as_str().unwrap().to_string();
                        if name == "thread_name" {
                            if let Some(tid) = tid {
                                if tid <= 3 {
                                    let label = e
                                        .get("args")
                                        .unwrap()
                                        .get("name")
                                        .unwrap()
                                        .as_str()
                                        .unwrap();
                                    threads.insert(tid, label.to_string());
                                }
                            }
                        }
                        metas.insert(name);
                    }
                    (Some("X"), Some(tid)) if tid <= 3 => {
                        cats.insert(e.get("cat").unwrap().as_str().unwrap().to_string());
                    }
                    _ => {}
                }
            }
            (threads, cats, metas)
        }

        let (p_threads, p_cats, p_metas) = vocab(&predicted);
        let (m_threads, m_cats, m_metas) = vocab(&measured);
        assert_eq!(p_threads, m_threads, "device tid -> thread-name maps must match");
        assert_eq!(p_threads.len(), 3);
        assert_eq!(p_cats, m_cats, "device-event cat vocabularies must match");
        let want: std::collections::BTreeSet<String> =
            ["Gpu", "Fpga", "Link"].iter().map(|s| s.to_string()).collect();
        assert_eq!(p_cats, want);
        for metas in [&p_metas, &m_metas] {
            assert!(metas.contains("process_name"), "{metas:?}");
            assert!(metas.contains("thread_name"), "{metas:?}");
        }
    }

    #[test]
    fn events_cover_all_three_engines() {
        let p = Planner::default();
        let g = models::shufflenetv2_05(224);
        let plan = p.plan_model_paper(&g);
        let text = model_trace_json(&plan, IdleParams::paper());
        let doc = json::parse(&text).unwrap();
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let mut tids = std::collections::BTreeSet::new();
        for e in events {
            if e.get("ph").and_then(json::Json::as_str) == Some("X") {
                tids.insert(e.get("tid").unwrap().as_usize().unwrap());
            }
        }
        assert_eq!(tids.into_iter().collect::<Vec<_>>(), vec![1, 2, 3]);
    }

    #[test]
    fn event_times_nonnegative_and_ordered_per_module() {
        let p = Planner::default();
        let g = models::squeezenet(224);
        let plan = p.plan_model_paper(&g);
        let text = model_trace_json(&plan, IdleParams::paper());
        let doc = json::parse(&text).unwrap();
        for e in doc.get("traceEvents").unwrap().as_arr().unwrap() {
            if e.get("ph").and_then(json::Json::as_str) == Some("X") {
                let ts = e.get("ts").unwrap().as_f64().unwrap();
                let dur = e.get("dur").unwrap().as_f64().unwrap();
                assert!(ts >= 0.0 && dur >= 0.0);
            }
        }
    }

    #[test]
    fn labels_are_escaped() {
        assert_eq!(escape("a\"b\\c"), "a\\\"b\\\\c");
        assert_eq!(escape("x\ny"), "x\\u000ay");
    }
}
