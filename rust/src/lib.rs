//! # hetero-dnn — FPGA-GPU heterogeneous embedded DNN inference
//!
//! Production-quality reproduction of *"Why is FPGA-GPU Heterogeneity the
//! Best Option for Embedded Deep Neural Networks?"* (Carballo-Hernández,
//! Pelcat, Berry — 2021).
//!
//! The paper partitions mobile CNN modules (SqueezeNet Fire, MobileNetV2
//! Bottleneck, ShuffleNetV2 units) between an embedded GPU (Jetson TX2) and
//! an FPGA running Direct-Hardware-Mapped layers (Cyclone 10 GX) linked by
//! PCIe gen2 x4, and shows the heterogeneous system beats the GPU-only
//! baseline in energy and/or latency.
//!
//! This crate is the **Layer-3 coordinator** of a three-layer Rust+JAX+Pallas
//! stack (see DESIGN.md):
//!
//! - [`graph`] — CNN graph IR + the three model builders.
//! - [`dhm`] — FPGA Direct Hardware Mapping simulator (resources, pipeline
//!   latency, Quartus-PE-style power) for the Cyclone 10 GX.
//! - [`gpu`] — Jetson TX2 roofline latency + energy model.
//! - [`link`] — PCIe gen2 x4 transfer model.
//! - [`partition`] — the paper's Fig 2 partitioning strategies.
//! - [`sched`] — event-timeline executor with parallel-branch latency hiding.
//! - [`hetero`] — the online heterogeneous executor: a partition plan
//!   served as a pipeline of simulated device stages (FPGA → PCIe link →
//!   GPU worker lanes with bounded queues), bit-identical to monolithic
//!   execution and throughput-faithful to the `sched::pipeline` analytic
//!   model.
//! - [`coordinator`] — the serving face: a multi-model, batch-first
//!   `Engine` (std-thread batchers + executor pools, typed requests with
//!   priorities/deadlines, shared admission with per-model budgets,
//!   content-digest result caching, and live model hot-swap via
//!   `Engine::register` / `Engine::retire`).
//! - [`obs`] — the flight recorder: per-request span events on
//!   fixed-capacity per-thread rings (never blocking the hot path),
//!   drained into per-stage latency breakdowns and a Chrome trace
//!   export of the *measured* run that loads side-by-side with the
//!   predicted `sched::trace` timeline (DESIGN.md §15).
//! - [`check`] — deterministic-schedule model checker for the serving
//!   stack's concurrency cores: a DFS explorer over named actions with
//!   asserter-style invariants and replayable failing schedules
//!   (DESIGN.md §11).
//! - [`cluster`] — the cluster tier: N in-process nodes (one `Engine`
//!   behind a v2 listener each) behind a digest-affinity router that
//!   fans pipelined client connections out over pooled upstream
//!   connections, with health/load-aware selection, bounded
//!   retry-with-failover, and rolling hot-swap across replicas
//!   (DESIGN.md §12).
//! - [`runtime`] — manifest-driven loader/executor for the AOT artifacts.
//!   Offline builds use the in-tree deterministic backend; a real PJRT
//!   backend is future work (DESIGN.md §Backends). Python never runs at
//!   inference time.
//! - [`quant`] — int8 fixed-point helpers mirroring the L1 Pallas kernels.
//! - [`metrics`] — latency/energy accounting and report emission.
//! - [`config`] — artifact manifest + device/experiment configuration.
//! - [`workloads`] — the traffic lab: named open-loop traffic scenarios
//!   as data, a seeded deterministic schedule builder + replay driver
//!   with per-scenario SLO reports, and the SLO-driven adaptive
//!   controller that re-places models live through the hot-swap seam
//!   (DESIGN.md §13).

pub mod check;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod dhm;
pub mod experiments;
pub mod gpu;
pub mod graph;
pub mod hetero;
pub mod link;
pub mod metrics;
pub mod obs;
pub mod partition;
pub mod quant;
pub mod runtime;
pub mod sched;
pub mod workloads;

pub use metrics::Cost;
