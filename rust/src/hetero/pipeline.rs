//! [`HeteroPipeline`]: one worker lane per device stage, connected by
//! **bounded queues**.
//!
//! Each stage of a [`HeteroExecutable`] gets its own thread pinned to a
//! simulated device ([`crate::runtime::device`]): the FPGA lane folds the
//! image plus its resident weight prefix and holds the FPGA for the
//! stage's modeled service time, the link lane bills the DMA crossing,
//! the GPU lane folds the rest and synthesizes the outputs. Stages hand
//! jobs over `sync_channel(queue_depth)` queues, so:
//!
//! - image *i+1* is serviced by the FPGA lane while image *i* occupies
//!   the GPU lane — the steady-state overlap `sched::pipeline` models;
//! - a stalled downstream lane **back-pressures** its upstream lane once
//!   the queue between them fills (and ultimately the engine's batcher,
//!   whose dispatch blocks on the intake queue);
//! - jobs complete in submission order (every lane is FIFO).
//!
//! Shutdown is by channel collapse: dropping the intake closes lane 0,
//! which drains its queue, completes its in-flight work and drops its
//! own sender — the same close → drain → join contract the worker pools
//! follow. Every accepted job is answered; none are dropped silently.

use super::executable::{HeteroExecutable, StageSpec};
use crate::coordinator::step;
use crate::metrics::device::HeteroMetrics;
use crate::obs::{LaneObs, Recorder, TraceId};
use crate::partition::Resource;
use crate::runtime::arbiter::{DeviceSet, TenantLease};
use crate::runtime::device::{Device, FpgaDevice, GpuDevice, LinkChannel, DEFAULT_TIME_SCALE};
use crate::runtime::{Literal, Runtime, RuntimeError, StagedRun, Tensor};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

/// Tuning knobs of one pipeline instance.
#[derive(Debug, Clone, Copy)]
pub struct PipelineConfig {
    /// Capacity of every inter-stage queue (>= 1). Small values give
    /// tight backpressure; larger ones smooth jitter between lanes.
    pub queue_depth: usize,
    /// Wall-clock seconds per simulated second for the device lanes
    /// ([`DEFAULT_TIME_SCALE`] by default; tests shrink it).
    pub time_scale: f64,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        Self { queue_depth: 2, time_scale: DEFAULT_TIME_SCALE }
    }
}

/// A completed image: the artifact's output tuple plus when the job
/// entered the first lane (the engine derives queue/exec splits from it).
#[derive(Debug)]
pub struct PipeOutput {
    /// The artifact's outputs, in manifest order.
    pub outputs: Vec<Tensor>,
    /// When the first lane started servicing this job.
    pub entered: Instant,
}

/// Completion callback: receives the caller's context back, with the
/// outputs or the job's terminal error. Called from a lane thread.
pub type OnDone<T> = Arc<dyn Fn(T, Result<PipeOutput, RuntimeError>) + Send + Sync>;

/// One job flowing through the lanes: caller context, the image literal
/// (consumed by the first lane's fold), and the digest-fold state — all
/// that crosses the simulated link.
struct Job<T> {
    ctx: T,
    input: Option<Literal>,
    state: Option<StagedRun>,
    entered: Option<Instant>,
    /// Flight-recorder identity, when the engine traced this request;
    /// lanes emit device span events against it (no-ops when `None`).
    trace: Option<TraceId>,
}

/// Cloneable handle feeding the first lane. `send` blocks while the
/// intake queue is full — this is the pipeline's backpressure surface.
pub struct Intake<T> {
    tx: mpsc::SyncSender<Job<T>>,
}

impl<T> Clone for Intake<T> {
    fn clone(&self) -> Self {
        Self { tx: self.tx.clone() }
    }
}

impl<T> Intake<T> {
    /// Submit one image. Blocks while the intake queue is full; returns
    /// the context back when the pipeline has shut down so the caller
    /// can answer the request itself.
    pub fn send(&self, ctx: T, input: Literal) -> Result<(), T> {
        self.send_traced(ctx, input, None)
    }

    /// [`Intake::send`] with the request's flight-recorder trace, so the
    /// lanes can span their device holds (see [`crate::obs`]).
    pub fn send_traced(&self, ctx: T, input: Literal, trace: Option<TraceId>) -> Result<(), T> {
        self.tx
            .send(Job { ctx, input: Some(input), state: None, entered: None, trace })
            .map_err(|mpsc::SendError(job)| job.ctx)
    }
}

/// A spawned pipeline's raw parts — the engine wires the intake into its
/// batcher and owns the lane threads through its pool bookkeeping;
/// [`HeteroPipeline`] wraps the same parts for standalone use.
pub struct SpawnedPipeline<T> {
    /// Feed for the first lane.
    pub intake: Intake<T>,
    /// Lane threads, in stage order; join after dropping every intake.
    pub threads: Vec<std::thread::JoinHandle<()>>,
    /// Shared per-device counters.
    pub metrics: Arc<HeteroMetrics>,
    /// The artifact's image input shape (front-door validation).
    pub input_shape: Vec<usize>,
    /// The artifact's image input name (error messages).
    pub input_arg: String,
}

/// Lane startup handshake payload: (input shape, input arg name).
type ReadyMsg = Result<(Vec<usize>, String), String>;

/// Spawn one lane thread per stage of `hexe`, each owning its runtime,
/// its weight span and a **private** simulated device. Fails — with
/// every spawned lane joined — if any lane cannot load the artifact or
/// synthesize its weights, so a half-started pipeline never leaks
/// threads.
pub fn spawn<T: Send + 'static>(
    artifact: &str,
    seed: u64,
    hexe: &HeteroExecutable,
    cfg: PipelineConfig,
    on_done: OnDone<T>,
) -> Result<SpawnedPipeline<T>, RuntimeError> {
    spawn_shared(artifact, seed, hexe, cfg, None, on_done)
}

/// [`spawn`], optionally over a node's shared [`DeviceSet`]: with
/// `devices` present the pipeline registers as one tenant and its lanes
/// *acquire* the node's GPU/FPGA/link per hold instead of owning private
/// silicon. The lanes share one tenant lease; when the last lane exits
/// the lease drops and the tenant retires from the arbiter.
pub fn spawn_shared<T: Send + 'static>(
    artifact: &str,
    seed: u64,
    hexe: &HeteroExecutable,
    cfg: PipelineConfig,
    devices: Option<Arc<DeviceSet>>,
    on_done: OnDone<T>,
) -> Result<SpawnedPipeline<T>, RuntimeError> {
    spawn_obs(artifact, seed, hexe, cfg, devices, None, on_done)
}

/// [`spawn_shared`], optionally observed by an engine's flight
/// [`Recorder`]: each lane gets a [`LaneObs`] handle over its own device
/// ring (tids shared with the predicted timeline) and emits
/// acquire/hold/release — and DMA crossings on the link — for every
/// traced job. With `obs` `None` (or jobs carrying no trace) the lanes
/// emit nothing and the hot path is untouched.
pub fn spawn_obs<T: Send + 'static>(
    artifact: &str,
    seed: u64,
    hexe: &HeteroExecutable,
    cfg: PipelineConfig,
    devices: Option<Arc<DeviceSet>>,
    obs: Option<Arc<Recorder>>,
    on_done: OnDone<T>,
) -> Result<SpawnedPipeline<T>, RuntimeError> {
    assert!(cfg.queue_depth >= 1, "queue_depth must be >= 1");
    let stages = hexe.stages().to_vec();
    let n = stages.len();
    let metrics = Arc::new(HeteroMetrics::default());
    let lease: Option<Arc<TenantLease>> =
        devices.as_ref().map(|set| Arc::new(set.register_tenant()));

    // build the queue chain first: intake -> lane 0 -> ... -> lane n-1
    let (intake_tx, first_rx) = mpsc::sync_channel::<Job<T>>(cfg.queue_depth);
    let mut rxs = vec![first_rx];
    let mut txs: Vec<Option<mpsc::SyncSender<Job<T>>>> = Vec::with_capacity(n);
    for _ in 1..n {
        let (tx, rx) = mpsc::sync_channel::<Job<T>>(cfg.queue_depth);
        txs.push(Some(tx));
        rxs.push(rx);
    }
    txs.push(None); // the last lane completes instead of forwarding

    let (ready_tx, ready_rx) = mpsc::channel::<ReadyMsg>();
    let mut threads = Vec::with_capacity(n);
    for (i, (spec, (rx, tx))) in
        stages.into_iter().zip(rxs.into_iter().zip(txs.into_iter())).enumerate()
    {
        let artifact = artifact.to_string();
        let metrics = metrics.clone();
        let on_done = on_done.clone();
        let ready = ready_tx.clone();
        let lease = lease.clone();
        let first = i == 0;
        let lane_obs = obs.as_ref().map(|r| r.lane_obs(spec.resource));
        let join = std::thread::Builder::new()
            .name(spec.label.clone())
            .spawn(move || {
                lane_loop(
                    spec,
                    artifact,
                    seed,
                    cfg.time_scale,
                    metrics,
                    lease,
                    lane_obs,
                    rx,
                    tx,
                    on_done,
                    first,
                    ready,
                )
            });
        match join {
            Ok(j) => threads.push(j),
            Err(e) => {
                // same cleanup contract as a failed handshake: collapse
                // the chain and join the lanes already spawned, so a
                // half-started pipeline never leaks detached threads
                drop(intake_tx);
                for j in threads {
                    let _ = j.join();
                }
                return Err(RuntimeError::Serving(format!("spawn hetero lane {i}: {e}")));
            }
        }
    }
    drop(ready_tx);

    // startup handshake: every lane must come up before any job is accepted
    let mut shape_arg: Option<(Vec<usize>, String)> = None;
    let mut failure: Option<RuntimeError> = None;
    for _ in 0..n {
        match ready_rx.recv() {
            Ok(Ok(sa)) => shape_arg = Some(sa),
            Ok(Err(msg)) => {
                failure = Some(RuntimeError::Serving(msg));
                break;
            }
            Err(_) => {
                failure = Some(RuntimeError::Serving("hetero lane died during startup".into()));
                break;
            }
        }
    }
    if let Some(e) = failure {
        drop(intake_tx); // collapse the chain: every lane drains and exits
        for j in threads {
            let _ = j.join();
        }
        return Err(e);
    }
    let (input_shape, input_arg) = shape_arg.expect("n >= 1 lanes handshake");
    Ok(SpawnedPipeline {
        intake: Intake { tx: intake_tx },
        threads,
        metrics,
        input_shape,
        input_arg,
    })
}

/// The lane's simulated device, picked by the stage's resource.
enum Lane {
    Gpu(GpuDevice),
    Fpga(FpgaDevice),
    Link(LinkChannel),
}

/// One step of a lane's per-job plan (see [`LaneCore::plan`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LaneOp {
    /// First lane only: stamp the job's entry time and open its fold
    /// state.
    Begin,
    /// Fold the image literal into the state (the image is consumed —
    /// from here on only the fold state crosses lanes).
    FoldImage,
    /// Fold this lane's resident weight span into the state.
    FoldWeights,
    /// Occupy the simulated device for the stage's modeled service time.
    Service,
    /// Last lane only: finish the fold and deliver the outputs.
    Complete,
    /// Interior lane: hand the job to the next lane's queue.
    Forward,
}

/// How a lane's per-job work ends (the value [`LaneOp::Complete`] /
/// [`LaneOp::Forward`] resolve to).
pub enum LaneOutcome {
    /// Last lane: the artifact's outputs, ready for the completion
    /// callback.
    Finished(Vec<Tensor>),
    /// Interior lane: the job continues downstream.
    Forward,
}

/// The lane loop's pure core: a lane's position in the chain decides its
/// per-job plan. The production shell executes the plan against the real
/// executable/device behind the dispatch-boundary panic guard; the
/// [`crate::check`] explorer schedules lane plans against bounded-queue
/// models without devices or clocks.
#[derive(Debug, Clone, Copy)]
pub struct LaneCore {
    first: bool,
    last: bool,
    folds_image: bool,
}

impl LaneCore {
    /// Core for one lane: chain position plus whether its fold span
    /// starts at index 0 (the image).
    pub fn new(first: bool, last: bool, folds_image: bool) -> Self {
        Self { first, last, folds_image }
    }

    /// The ordered per-job plan. A fold failure aborts the plan — the
    /// job is answered with the error and the device is **not** held.
    pub fn plan(&self) -> Vec<LaneOp> {
        let mut ops = Vec::with_capacity(5);
        if self.first {
            ops.push(LaneOp::Begin);
        }
        if self.folds_image {
            ops.push(LaneOp::FoldImage);
        }
        ops.push(LaneOp::FoldWeights);
        ops.push(LaneOp::Service);
        ops.push(if self.last { LaneOp::Complete } else { LaneOp::Forward });
        ops
    }
}

#[allow(clippy::too_many_arguments)]
fn lane_loop<T: Send>(
    spec: StageSpec,
    artifact: String,
    seed: u64,
    time_scale: f64,
    metrics: Arc<HeteroMetrics>,
    lease: Option<Arc<TenantLease>>,
    obs: Option<LaneObs>,
    rx: mpsc::Receiver<Job<T>>,
    tx: Option<mpsc::SyncSender<Job<T>>>,
    on_done: OnDone<T>,
    first: bool,
    ready: mpsc::Sender<ReadyMsg>,
) {
    // --- startup: runtime, artifact, this lane's weight span
    let rt = Runtime::new_or_simulated();
    let exe = match rt.load(&artifact) {
        Ok(e) => e,
        Err(e) => {
            let _ = ready.send(Err(format!("{}: load {artifact}: {e}", spec.label)));
            return;
        }
    };
    if exe.entry.inputs.is_empty() || exe.entry.outputs.is_empty() {
        let _ = ready.send(Err(format!("artifact {artifact} needs inputs and outputs")));
        return;
    }
    // weight indices this lane folds (index 0, the image, arrives per
    // job). Only THIS lane's span is synthesized and kept — generating
    // the full input set in every lane would triple both the startup
    // compute and the model's resident footprint.
    let w_lo = spec.fold.start.max(1);
    let w_hi = spec.fold.end.max(w_lo);
    let mut span: Vec<Tensor> = Vec::with_capacity(w_hi - w_lo);
    for idx in w_lo..w_hi {
        match rt.synth_input(&artifact, seed, idx) {
            Ok(t) => span.push(t),
            Err(e) => {
                let _ = ready.send(Err(format!("{}: synth input {idx}: {e}", spec.label)));
                return;
            }
        }
    }
    let weight_lits = match exe.prepare(&span, w_lo) {
        Ok(v) => v,
        Err(e) => {
            let _ = ready.send(Err(format!("{}: prepare weights: {e}", spec.label)));
            return;
        }
    };
    drop(span); // prepare cloned the tensors into literals
    // the span's ref list is invariant across jobs: build it once, not
    // per image on the serving hot path
    let weight_refs: Vec<&Literal> = weight_lits.iter().collect();
    let _ = ready.send(Ok((exe.entry.inputs[0].shape.clone(), exe.entry.inputs[0].name.clone())));

    let lane = match (spec.resource, lease) {
        (Resource::Gpu, None) => Lane::Gpu(GpuDevice::new(metrics.clone(), time_scale)),
        (Resource::Fpga, None) => Lane::Fpga(FpgaDevice::new(metrics.clone(), time_scale)),
        (Resource::Link, None) => Lane::Link(LinkChannel::new(metrics.clone(), time_scale)),
        (Resource::Gpu, Some(l)) => Lane::Gpu(GpuDevice::shared(metrics.clone(), time_scale, l)),
        (Resource::Fpga, Some(l)) => Lane::Fpga(FpgaDevice::shared(metrics.clone(), time_scale, l)),
        (Resource::Link, Some(l)) => Lane::Link(LinkChannel::shared(metrics.clone(), time_scale, l)),
    };
    let last = tx.is_none();
    let core = LaneCore::new(first, last, spec.fold.start == 0 && !spec.fold.is_empty());

    // --- serve until the upstream sender (intake or previous lane)
    // closes. The job's context stays OUTSIDE the panic guard: whatever
    // happens inside the plan — a fold error or a contained panic — the
    // job is still answered through the completion callback, never
    // stranded (the panic-safety contract the regression tests pin).
    while let Ok(job) = rx.recv() {
        let Job { ctx, mut input, mut state, mut entered, trace } = job;
        let outcome = step::catch_dispatch_panic(|| {
            step::fire_injected_panic(&artifact);
            for op in core.plan() {
                match op {
                    LaneOp::Begin => {
                        entered = Some(Instant::now());
                        state = Some(exe.stage_begin());
                    }
                    LaneOp::FoldImage => {
                        let st = state.as_mut().expect("state set by the first lane");
                        let image = input.take().expect("image folded exactly once");
                        exe.stage_fold(st, &[&image])?;
                        // the image buffer is dropped here: from now on
                        // only the fold state (the simulated feature map)
                        // crosses lanes
                    }
                    LaneOp::FoldWeights => {
                        let st = state.as_mut().expect("state set by the first lane");
                        exe.stage_fold(st, &weight_refs)?;
                    }
                    LaneOp::Service => {
                        if let Some(o) = &obs {
                            o.acquire(trace);
                        }
                        let hs = match &lane {
                            Lane::Gpu(d) => d.service(spec.cost),
                            Lane::Fpga(d) => d.service(spec.cost),
                            Lane::Link(d) => {
                                let hs = d.dma(
                                    spec.transfer_elems as u64,
                                    spec.transfer_bytes as u64,
                                    spec.cost,
                                );
                                if let Some(o) = &obs {
                                    o.dma(trace, spec.transfer_bytes as u64);
                                }
                                hs
                            }
                        };
                        if let Some(o) = &obs {
                            o.release(trace, hs.wait_us(), hs.held_us());
                        }
                    }
                    LaneOp::Complete => {
                        let st = state.take().expect("state present at the last lane");
                        return exe.stage_finish(st).map(LaneOutcome::Finished);
                    }
                    LaneOp::Forward => return Ok(LaneOutcome::Forward),
                }
            }
            unreachable!("a lane plan always ends in Complete or Forward")
        });
        match outcome {
            Ok(LaneOutcome::Finished(outputs)) => {
                let entered = entered.expect("entered stamped by the first lane");
                metrics.record_image();
                on_done(ctx, Ok(PipeOutput { outputs, entered }));
            }
            Ok(LaneOutcome::Forward) => {
                let next = tx.as_ref().expect("interior lanes have a downstream queue");
                if let Err(mpsc::SendError(job)) =
                    next.send(Job { ctx, input, state, entered, trace })
                {
                    // downstream lane gone (shutdown raced a failure):
                    // answer the job instead of dropping it
                    on_done(
                        job.ctx,
                        Err(RuntimeError::Serving("hetero pipeline shutting down".into())),
                    );
                }
            }
            Err(e) => on_done(ctx, Err(e)),
        }
    }
    // rx closed: upstream drained and dropped its sender; dropping ours
    // (if any) collapses the rest of the chain
}

/// A standalone pipeline handle (examples, benches, tests): owns the
/// intake and the lane threads, validates inputs at submit, and joins
/// everything on [`HeteroPipeline::shutdown`].
///
/// The serving engine does not use this wrapper — it wires
/// [`SpawnedPipeline`]'s parts into its own batcher/pool lifecycle.
pub struct HeteroPipeline<T: Send + 'static> {
    intake: Option<Intake<T>>,
    threads: Vec<std::thread::JoinHandle<()>>,
    /// Shared per-device counters.
    pub metrics: Arc<HeteroMetrics>,
    input_shape: Vec<usize>,
    input_arg: String,
    artifact: String,
}

impl<T: Send + 'static> HeteroPipeline<T> {
    /// Spawn the lanes for `hexe` and return a running pipeline.
    pub fn start(
        artifact: &str,
        seed: u64,
        hexe: &HeteroExecutable,
        cfg: PipelineConfig,
        on_done: OnDone<T>,
    ) -> Result<Self, RuntimeError> {
        Self::start_shared(artifact, seed, hexe, cfg, None, on_done)
    }

    /// [`HeteroPipeline::start`], optionally as one tenant of a node's
    /// shared [`DeviceSet`] (see [`spawn_shared`]).
    pub fn start_shared(
        artifact: &str,
        seed: u64,
        hexe: &HeteroExecutable,
        cfg: PipelineConfig,
        devices: Option<Arc<DeviceSet>>,
        on_done: OnDone<T>,
    ) -> Result<Self, RuntimeError> {
        let sp = spawn_shared(artifact, seed, hexe, cfg, devices, on_done)?;
        Ok(Self {
            intake: Some(sp.intake),
            threads: sp.threads,
            metrics: sp.metrics,
            input_shape: sp.input_shape,
            input_arg: sp.input_arg,
            artifact: artifact.to_string(),
        })
    }

    /// The artifact's image input shape.
    pub fn input_shape(&self) -> &[usize] {
        &self.input_shape
    }

    /// Submit one image (validated against the artifact's input shape).
    /// Blocks while the intake queue is full — backpressure reaches the
    /// caller directly.
    pub fn submit(&self, ctx: T, input: Tensor) -> Result<(), RuntimeError> {
        if input.shape != self.input_shape {
            return Err(RuntimeError::ShapeMismatch {
                name: self.artifact.clone(),
                index: 0,
                arg: self.input_arg.clone(),
                expected: self.input_shape.clone(),
                got: input.shape,
            });
        }
        let intake = self
            .intake
            .as_ref()
            .ok_or_else(|| RuntimeError::Serving("hetero pipeline is shut down".into()))?;
        intake
            .send(ctx, Literal::from_tensor(input))
            .map_err(|_| RuntimeError::Serving("hetero pipeline is shut down".into()))
    }

    /// Close the intake, drain every lane and join the threads. In-flight
    /// jobs complete and deliver through the completion callback first.
    pub fn shutdown(mut self) {
        self.intake.take();
        for j in self.threads.drain(..) {
            let _ = j.join();
        }
    }
}

impl<T: Send + 'static> Drop for HeteroPipeline<T> {
    fn drop(&mut self) {
        self.intake.take();
        for j in self.threads.drain(..) {
            let _ = j.join();
        }
    }
}
