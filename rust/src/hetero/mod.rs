//! Online heterogeneous execution: serve a model as a **pipeline of
//! simulated device stages** (FPGA → PCIe link → GPU) instead of a flat
//! worker pool.
//!
//! The paper's central result is that *hybrid* FPGA-GPU execution beats
//! GPU-only even after paying PCIe communication — and its DHM substrate
//! is throughput-optimised and pipelined (§I): once a module's layers are
//! resident, the FPGA can accept image *i+1* while the GPU still works on
//! image *i*. The offline stack models that steady state analytically
//! ([`crate::sched::pipeline`]); this subsystem **runs** it:
//!
//! 1. [`stage_profile`] reduces a [`ModelPlan`] to per-device service
//!    demand — exactly the reduction `sched::pipeline::service_demand`
//!    performs, but keeping the per-resource energy and link traffic the
//!    online devices need.
//! 2. [`HeteroExecutable`] splits the model's input chain at the plan's
//!    device boundary into per-stage folds of the runtime's staged
//!    execution seam ([`crate::runtime::StagedRun`]), so a split run is
//!    **bit-identical** to the monolithic `run_batch` path by
//!    construction.
//! 3. [`pipeline::HeteroPipeline`] runs one worker lane per stage on the
//!    simulated devices ([`crate::runtime::device`]), connected by
//!    **bounded queues**: a full downstream stage back-pressures its
//!    upstream lane, and the measured steady-state throughput converges
//!    to `1 / bottleneck` — the analytic prediction, now observable with
//!    a stopwatch.
//!
//! The serving [`crate::coordinator::Engine`] dispatches a model here
//! instead of its flat pool when its spec asks for
//! `ModelSpec::placement(strategy)`; per-device occupancy/transfer/energy
//! counters land in [`crate::metrics::device::HeteroMetrics`].

#![warn(missing_docs)]

pub mod executable;
pub mod pipeline;

pub use executable::{HeteroExecutable, StageSpec};
pub use pipeline::{HeteroPipeline, PipelineConfig};

use crate::metrics::Cost;
use crate::partition::{ModelPlan, Step};

/// Per-image service demand of a plan, split by device — the online twin
/// of `sched::pipeline::ServiceDemand`, extended with per-resource energy
/// and link traffic so simulated devices can bill both time and joules.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StageProfile {
    /// GPU busy time + active energy for one image.
    pub gpu: Cost,
    /// FPGA busy time + active energy for one image.
    pub fpga: Cost,
    /// Link busy time + active energy for one image (both directions).
    pub link: Cost,
    /// Feature-map elements crossing the link per image.
    pub transfer_elems: usize,
    /// Bytes crossing the link per image (precision-weighted).
    pub transfer_bytes: usize,
}

impl StageProfile {
    /// Total active cost of one image (all devices).
    pub fn total(&self) -> Cost {
        self.gpu.then(self.fpga).then(self.link)
    }

    /// The per-image service time of the slowest device — the analytic
    /// steady-state pipeline period.
    pub fn bottleneck_seconds(&self) -> f64 {
        self.gpu.seconds.max(self.fpga.seconds).max(self.link.seconds)
    }
}

fn walk(steps: &[Step], p: &mut StageProfile) {
    for s in steps {
        match s {
            Step::Gpu { cost, .. } | Step::GpuData { cost, .. } => p.gpu = p.gpu.then(*cost),
            Step::Fpga { cost, .. } => p.fpga = p.fpga.then(*cost),
            Step::Transfer { cost, elems, prec, .. } => {
                p.link = p.link.then(*cost);
                p.transfer_elems += elems;
                p.transfer_bytes += elems * prec.bytes();
            }
            Step::Parallel { gpu, fpga } => {
                walk(gpu, p);
                walk(fpga, p);
            }
        }
    }
}

/// Reduce a whole-model plan to its per-device, per-image service demand.
///
/// Busy seconds agree with `sched::pipeline::service_demand` (same walk,
/// same costs); energy is the *active* energy split by the device that
/// burns it, so `profile.total().joules` equals the demand's active
/// joules under the paper's no-idle-billing methodology.
pub fn stage_profile(plan: &ModelPlan) -> StageProfile {
    let mut p = StageProfile::default();
    for m in &plan.modules {
        walk(&m.steps, &mut p);
    }
    p
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::partition::{Planner, Strategy};
    use crate::sched::pipeline::service_demand;

    #[test]
    fn profile_busy_matches_service_demand() {
        // the online reduction must agree with the analytic one — the
        // property the measured-vs-predicted throughput tests build on
        let p = Planner::default();
        for g in models::all_models() {
            for strat in [Strategy::GpuOnly, Strategy::Paper, Strategy::Auto] {
                let plan = p.plan_model(&g, strat);
                let prof = stage_profile(&plan);
                let d = service_demand(&plan);
                let close = |a: f64, b: f64| (a - b).abs() <= 1e-12 * b.abs().max(1.0);
                assert!(close(prof.gpu.seconds, d.gpu), "{} {strat}: gpu", g.name);
                assert!(close(prof.fpga.seconds, d.fpga), "{} {strat}: fpga", g.name);
                assert!(close(prof.link.seconds, d.link), "{} {strat}: link", g.name);
                assert!(
                    close(prof.total().joules, d.joules),
                    "{} {strat}: active energy {} vs {}",
                    g.name,
                    prof.total().joules,
                    d.joules
                );
            }
        }
    }

    #[test]
    fn gpu_only_profile_has_idle_fpga_and_link() {
        let p = Planner::default();
        let g = models::squeezenet(224);
        let prof = stage_profile(&p.plan_model(&g, Strategy::GpuOnly));
        assert!(prof.gpu.seconds > 0.0);
        assert_eq!(prof.fpga, Cost::ZERO);
        assert_eq!(prof.link, Cost::ZERO);
        assert_eq!(prof.transfer_elems, 0);
        assert!((prof.bottleneck_seconds() - prof.gpu.seconds).abs() < 1e-15);
    }

    #[test]
    fn hetero_profile_moves_work_off_the_gpu() {
        // the throughput face of the paper's claim: the hybrid plan's
        // bottleneck stage is strictly faster than the GPU-only stage
        let p = Planner::default();
        for g in models::all_models() {
            let base = stage_profile(&p.plan_model(&g, Strategy::GpuOnly));
            let het = stage_profile(&p.plan_model(&g, Strategy::Paper));
            assert!(het.fpga.seconds > 0.0, "{}: nothing offloaded", g.name);
            assert!(het.transfer_elems > 0, "{}: no link traffic", g.name);
            assert!(
                het.bottleneck_seconds() < base.bottleneck_seconds(),
                "{}: hybrid bottleneck {} !< gpu-only {}",
                g.name,
                het.bottleneck_seconds(),
                base.bottleneck_seconds()
            );
        }
    }
}
