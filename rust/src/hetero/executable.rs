//! [`HeteroExecutable`]: a model artifact split at its plan's device
//! boundary into per-stage input folds.
//!
//! On the deterministic backend an artifact is a pure function of the
//! digest-fold of its inputs (image, then every layer's weights in module
//! order — `config::sim::net_entry` geometry). Splitting the *layer
//! chain* between devices therefore means splitting the *input chain*:
//! the FPGA lane folds the image plus the weight prefix of its resident
//! layers, only the fold state (the stand-in for the boundary feature
//! map) crosses the link lane, and the GPU lane folds the remaining
//! weights and synthesizes the logits. Because all three lanes apply the
//! one shared fold definition ([`crate::runtime::StagedRun`]), the split
//! execution is **bit-identical** to the monolithic
//! `Executable::run_batch` path — the online analogue of
//! `ChainExecutor::run_hetero`'s F32 exactness claim.
//!
//! The cut point follows the plan: the FPGA lane's share of the weight
//! chain equals its share of modeled compute ([`stage_profile`] —
//! shared-fabric plans that offload little fold little), mirroring how
//! `sched::pipeline` aggregates per-module splits into per-resource
//! service demand.

use super::{stage_profile, StageProfile};
use crate::metrics::Cost;
use crate::partition::{ModelPlan, Resource};
use crate::runtime::{Executable, Literal, RuntimeError, Tensor};
use std::ops::Range;

/// One pipeline stage: the device lane it occupies, its per-image service
/// cost, and the span of artifact inputs whose digest fold it owns.
#[derive(Debug, Clone)]
pub struct StageSpec {
    /// Device lane this stage occupies.
    pub resource: Resource,
    /// Human-readable lane label (serve summary, thread names).
    pub label: String,
    /// Per-image service time + active energy on this lane.
    pub cost: Cost,
    /// Artifact input indices folded on this stage (index 0 is the image).
    pub fold: Range<usize>,
    /// Link lanes: feature-map elements crossing per image.
    pub transfer_elems: usize,
    /// Link lanes: bytes crossing per image.
    pub transfer_bytes: usize,
}

/// A model split into device stages, ready to run staged (synchronously
/// via [`HeteroExecutable::run`], or pipelined via
/// [`super::pipeline::HeteroPipeline`]).
#[derive(Debug, Clone)]
pub struct HeteroExecutable {
    /// The plan's model name (labels, summaries).
    pub model: String,
    stages: Vec<StageSpec>,
    profile: StageProfile,
    n_inputs: usize,
}

impl HeteroExecutable {
    /// Split an artifact with `n_inputs` manifest inputs (1 image +
    /// `n_inputs - 1` weights) at `plan`'s device boundary.
    ///
    /// A plan that never touches the FPGA yields a single GPU stage (the
    /// GPU-only serving baseline, paying its full service demand on one
    /// lane); a heterogeneous plan yields the three-lane FPGA → link →
    /// GPU pipeline.
    ///
    /// # Panics
    /// Panics when `n_inputs` is zero — every served artifact takes at
    /// least its image input (the engine validates this at startup).
    pub fn from_plan(plan: &ModelPlan, n_inputs: usize) -> Self {
        assert!(n_inputs > 0, "artifact must take at least the image input");
        let profile = stage_profile(plan);
        let n_weights = n_inputs - 1;
        let stages = if !plan.uses_fpga() || profile.fpga.seconds <= 0.0 {
            vec![StageSpec {
                resource: Resource::Gpu,
                label: format!("{}:gpu", plan.model_name),
                cost: profile.total(),
                fold: 0..n_inputs,
                transfer_elems: 0,
                transfer_bytes: 0,
            }]
        } else {
            // the FPGA lane's share of the weight chain tracks its share
            // of modeled compute; the cut is the online device boundary
            let share = profile.fpga.seconds / (profile.fpga.seconds + profile.gpu.seconds);
            let k = ((n_weights as f64 * share).round() as usize).min(n_weights);
            vec![
                StageSpec {
                    resource: Resource::Fpga,
                    label: format!("{}:fpga", plan.model_name),
                    cost: profile.fpga,
                    fold: 0..1 + k,
                    transfer_elems: 0,
                    transfer_bytes: 0,
                },
                StageSpec {
                    resource: Resource::Link,
                    label: format!("{}:link", plan.model_name),
                    cost: profile.link,
                    fold: 1 + k..1 + k,
                    transfer_elems: profile.transfer_elems,
                    transfer_bytes: profile.transfer_bytes,
                },
                StageSpec {
                    resource: Resource::Gpu,
                    label: format!("{}:gpu", plan.model_name),
                    cost: profile.gpu,
                    fold: 1 + k..n_inputs,
                    transfer_elems: 0,
                    transfer_bytes: 0,
                },
            ]
        };
        Self { model: plan.model_name.clone(), stages, profile, n_inputs }
    }

    /// The pipeline stages, in dataflow order.
    pub fn stages(&self) -> &[StageSpec] {
        &self.stages
    }

    /// The per-device service demand the stages were derived from.
    pub fn profile(&self) -> &StageProfile {
        &self.profile
    }

    /// Manifest inputs the underlying artifact takes.
    pub fn n_inputs(&self) -> usize {
        self.n_inputs
    }

    /// The lane bounding steady-state throughput and its per-image
    /// service time — the analytic prediction the measured pipeline is
    /// tested against.
    pub fn bottleneck(&self) -> (Resource, f64) {
        self.stages
            .iter()
            .map(|s| (s.resource, s.cost.seconds))
            .max_by(|a, b| a.1.total_cmp(&b.1))
            .expect("at least one stage")
    }

    /// Run one input list through every stage **synchronously** (no lane
    /// threads, no simulated device time): the pure numeric semantics of
    /// the pipeline, which the bit-identity tests compare against the
    /// monolithic `run_batch` path. `literals` is the full positional
    /// input list (image first, then weights) in manifest order.
    pub fn run(
        &self,
        exe: &Executable,
        literals: &[&Literal],
    ) -> Result<Vec<Tensor>, RuntimeError> {
        if literals.len() != self.n_inputs {
            return Err(RuntimeError::ArityMismatch {
                name: exe.name.clone(),
                expected: self.n_inputs,
                got: literals.len(),
            });
        }
        let mut run = exe.stage_begin();
        for stage in &self.stages {
            exe.stage_fold(&mut run, &literals[stage.fold.clone()])?;
        }
        exe.stage_finish(run)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::partition::{Planner, Strategy};

    #[test]
    fn stages_partition_the_input_chain() {
        let p = Planner::default();
        for g in models::all_models() {
            let plan = p.plan_model(&g, Strategy::Paper);
            let hexe = HeteroExecutable::from_plan(&plan, 27);
            assert_eq!(hexe.stages().len(), 3, "{}", g.name);
            // spans are contiguous, start at 0, end at n_inputs
            let mut next = 0;
            for s in hexe.stages() {
                assert_eq!(s.fold.start, next, "{}: gap in fold spans", g.name);
                next = s.fold.end;
            }
            assert_eq!(next, 27);
            // the image belongs to the first stage; the link folds nothing
            assert_eq!(hexe.stages()[0].resource, Resource::Fpga);
            assert!(hexe.stages()[0].fold.contains(&0));
            assert!(hexe.stages()[1].fold.is_empty());
            assert!(hexe.stages()[1].transfer_elems > 0);
        }
    }

    #[test]
    fn gpu_only_plan_is_a_single_stage() {
        let p = Planner::default();
        let g = models::squeezenet(224);
        let plan = p.plan_model(&g, Strategy::GpuOnly);
        let hexe = HeteroExecutable::from_plan(&plan, 27);
        assert_eq!(hexe.stages().len(), 1);
        assert_eq!(hexe.stages()[0].resource, Resource::Gpu);
        assert_eq!(hexe.stages()[0].fold, 0..27);
        assert_eq!(hexe.bottleneck().0, Resource::Gpu);
    }

    #[test]
    fn bottleneck_is_the_slowest_stage() {
        let p = Planner::default();
        let g = models::squeezenet(224);
        let plan = p.plan_model(&g, Strategy::Paper);
        let hexe = HeteroExecutable::from_plan(&plan, 27);
        let (_, period) = hexe.bottleneck();
        assert!((period - hexe.profile().bottleneck_seconds()).abs() < 1e-15);
        for s in hexe.stages() {
            assert!(s.cost.seconds <= period + 1e-15);
        }
    }
}
