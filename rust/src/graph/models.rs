//! Builders for the paper's three mobile CNNs.
//!
//! Hyper-parameters come from the original papers at the widths the paper
//! evaluates (MobileNetV2 0.5x, ShuffleNetV2 0.5x, SqueezeNet v1.0) and
//! mirror `python/compile/model.py` exactly — integration tests cross-check
//! these tables against the AOT manifest geometry.
//!
//! Layer role order inside each [`Module`] is a contract with
//! [`crate::partition`]:
//!   Fire          = [squeeze_pw, expand1_pw, expand3_conv]
//!   Bottleneck    = [expand_pw?, dw, project_pw]
//!   ShuffleBasic  = [right_pw1, right_dw, right_pw2]      (on C/2 channels)
//!   ShuffleReduce = [left_dw, left_pw, right_pw1, right_dw, right_pw2]
//!   Plain / Pool  = [single layer]

use super::{Activation, Layer, ModelGraph, Module, ModuleKind, OpKind, TensorShape};

fn plain(name: &str, op: OpKind, input: TensorShape) -> Module {
    let l = Layer::new(op, input);
    Module {
        name: name.to_string(),
        kind: if matches!(op, OpKind::MaxPool { .. }) { ModuleKind::Pool } else { ModuleKind::Plain },
        layers: vec![l],
        input,
        output: l.output,
    }
}

/// SqueezeNet Fire module: squeeze -> {expand1x1 || expand3x3} -> concat.
pub fn fire(name: &str, input: TensorShape, s: usize, e1: usize, e3: usize) -> Module {
    let squeeze = Layer::new(OpKind::PwConv { cout: s, act: Activation::Relu }, input);
    let expand1 = Layer::new(OpKind::PwConv { cout: e1, act: Activation::Relu }, squeeze.output);
    let expand3 = Layer::new(
        OpKind::Conv { k: 3, stride: 1, pad: 1, cout: e3, act: Activation::Relu },
        squeeze.output,
    );
    let output = TensorShape::new(expand1.output.h, expand1.output.w, e1 + e3);
    Module {
        name: name.to_string(),
        kind: ModuleKind::Fire,
        layers: vec![squeeze, expand1, expand3],
        input,
        output,
    }
}

/// MobileNetV2 inverted bottleneck.
pub fn bottleneck(name: &str, input: TensorShape, cout: usize, expand: usize, stride: usize) -> Module {
    let mut layers = Vec::new();
    let mut cur = input;
    if expand != 1 {
        let e = Layer::new(
            OpKind::PwConv { cout: input.c * expand, act: Activation::Relu6 },
            cur,
        );
        cur = e.output;
        layers.push(e);
    }
    let dw = Layer::new(OpKind::DwConv { k: 3, stride, act: Activation::Relu6 }, cur);
    cur = dw.output;
    layers.push(dw);
    let proj = Layer::new(OpKind::PwConv { cout, act: Activation::None }, cur);
    let residual = stride == 1 && cout == input.c;
    let output = proj.output;
    layers.push(proj);
    Module {
        name: name.to_string(),
        kind: ModuleKind::Bottleneck { residual },
        layers,
        input,
        output,
    }
}

/// ShuffleNetV2 basic (stride-1) unit: right branch works on C/2 channels.
pub fn shuffle_basic(name: &str, input: TensorShape) -> Module {
    let ch = input.c / 2;
    let half = TensorShape::new(input.h, input.w, ch);
    let pw1 = Layer::new(OpKind::PwConv { cout: ch, act: Activation::Relu }, half);
    let dw = Layer::new(OpKind::DwConv { k: 3, stride: 1, act: Activation::None }, pw1.output);
    let pw2 = Layer::new(OpKind::PwConv { cout: ch, act: Activation::Relu }, dw.output);
    Module {
        name: name.to_string(),
        kind: ModuleKind::ShuffleBasic,
        layers: vec![pw1, dw, pw2],
        input,
        output: input,
    }
}

/// ShuffleNetV2 spatial-reduction (stride-2) unit: ci -> co, both branches.
pub fn shuffle_reduce(name: &str, input: TensorShape, cout: usize) -> Module {
    let ch = cout / 2;
    let left_dw = Layer::new(OpKind::DwConv { k: 3, stride: 2, act: Activation::None }, input);
    let left_pw = Layer::new(OpKind::PwConv { cout: ch, act: Activation::Relu }, left_dw.output);
    let right_pw1 = Layer::new(OpKind::PwConv { cout: ch, act: Activation::Relu }, input);
    let right_dw = Layer::new(OpKind::DwConv { k: 3, stride: 2, act: Activation::None }, right_pw1.output);
    let right_pw2 = Layer::new(OpKind::PwConv { cout: ch, act: Activation::Relu }, right_dw.output);
    let output = TensorShape::new(left_pw.output.h, left_pw.output.w, cout);
    Module {
        name: name.to_string(),
        kind: ModuleKind::ShuffleReduce,
        layers: vec![left_dw, left_pw, right_pw1, right_dw, right_pw2],
        input,
        output,
    }
}

/// SqueezeNet v1.0 fire configs: (squeeze, expand1, expand3).
pub const SQUEEZENET_FIRES: [(usize, usize, usize); 8] = [
    (16, 64, 64),
    (16, 64, 64),
    (32, 128, 128),
    (32, 128, 128),
    (48, 192, 192),
    (48, 192, 192),
    (64, 256, 256),
    (64, 256, 256),
];

/// SqueezeNet v1.0 at the given square input resolution.
pub fn squeezenet(res: usize) -> ModelGraph {
    let input = TensorShape::new(res, res, 3);
    let mut modules = Vec::new();
    let stem = plain(
        "conv1",
        OpKind::Conv { k: 7, stride: 2, pad: 0, cout: 96, act: Activation::Relu },
        input,
    );
    let mut cur = stem.output;
    modules.push(stem);
    let pool1 = plain("pool1", OpKind::MaxPool { k: 3, stride: 2 }, cur);
    cur = pool1.output;
    modules.push(pool1);
    for (i, &(s, e1, e3)) in SQUEEZENET_FIRES.iter().enumerate() {
        let m = fire(&format!("fire{}", i + 2), cur, s, e1, e3);
        cur = m.output;
        modules.push(m);
        if i == 2 || i == 6 {
            let p = plain(&format!("pool{}", i), OpKind::MaxPool { k: 3, stride: 2 }, cur);
            cur = p.output;
            modules.push(p);
        }
    }
    let conv10 = plain("conv10", OpKind::PwConv { cout: 1000, act: Activation::Relu }, cur);
    cur = conv10.output;
    modules.push(conv10);
    modules.push(plain("gap", OpKind::GlobalAvgPool, cur));
    let g = ModelGraph { name: "squeezenet".into(), input, modules };
    debug_assert!(g.validate().is_ok());
    g
}

/// MobileNetV2 0.5x setting: (expand t, c_out, repeats n, first stride s).
pub const MOBILENETV2_05_SETTING: [(usize, usize, usize, usize); 7] = [
    (1, 8, 1, 1),
    (6, 16, 2, 2),
    (6, 16, 3, 2),
    (6, 32, 4, 2),
    (6, 48, 3, 1),
    (6, 80, 3, 2),
    (6, 160, 1, 1),
];

/// MobileNetV2 x0.5 at the given square input resolution.
pub fn mobilenetv2_05(res: usize) -> ModelGraph {
    let input = TensorShape::new(res, res, 3);
    let mut modules = Vec::new();
    let stem = plain(
        "stem",
        OpKind::Conv { k: 3, stride: 2, pad: 1, cout: 16, act: Activation::Relu6 },
        input,
    );
    let mut cur = stem.output;
    modules.push(stem);
    for (bi, &(t, c, n, s)) in MOBILENETV2_05_SETTING.iter().enumerate() {
        for ri in 0..n {
            let stride = if ri == 0 { s } else { 1 };
            let m = bottleneck(&format!("bn{}_{}", bi, ri), cur, c, t, stride);
            cur = m.output;
            modules.push(m);
        }
    }
    let last = plain("last", OpKind::PwConv { cout: 1280, act: Activation::Relu6 }, cur);
    cur = last.output;
    modules.push(last);
    let gap = plain("gap", OpKind::GlobalAvgPool, cur);
    cur = gap.output;
    modules.push(gap);
    modules.push(plain("fc", OpKind::Dense { cout: 1000 }, cur));
    let g = ModelGraph { name: "mobilenetv2_05".into(), input, modules };
    debug_assert!(g.validate().is_ok());
    g
}

/// ShuffleNetV2 0.5x stages: (c_out, repeats).
pub const SHUFFLENETV2_05_STAGES: [(usize, usize); 3] = [(48, 4), (96, 8), (192, 4)];

/// ShuffleNetV2 x0.5 at the given square input resolution.
pub fn shufflenetv2_05(res: usize) -> ModelGraph {
    let input = TensorShape::new(res, res, 3);
    let mut modules = Vec::new();
    let stem = plain(
        "stem",
        OpKind::Conv { k: 3, stride: 2, pad: 1, cout: 24, act: Activation::Relu },
        input,
    );
    let mut cur = stem.output;
    modules.push(stem);
    let pool = plain("pool1", OpKind::MaxPool { k: 3, stride: 2 }, cur);
    cur = pool.output;
    modules.push(pool);
    for (si, &(c, n)) in SHUFFLENETV2_05_STAGES.iter().enumerate() {
        let r = shuffle_reduce(&format!("s{}_red", si + 2), cur, c);
        cur = r.output;
        modules.push(r);
        for ri in 0..n - 1 {
            let b = shuffle_basic(&format!("s{}_b{}", si + 2, ri), cur);
            cur = b.output;
            modules.push(b);
        }
    }
    let last = plain("last", OpKind::PwConv { cout: 1024, act: Activation::Relu }, cur);
    cur = last.output;
    modules.push(last);
    let gap = plain("gap", OpKind::GlobalAvgPool, cur);
    cur = gap.output;
    modules.push(gap);
    modules.push(plain("fc", OpKind::Dense { cout: 1000 }, cur));
    let g = ModelGraph { name: "shufflenetv2_05".into(), input, modules };
    debug_assert!(g.validate().is_ok());
    g
}

/// All three evaluation models at the paper's 224x224 resolution.
pub fn all_models() -> Vec<ModelGraph> {
    vec![squeezenet(224), mobilenetv2_05(224), shufflenetv2_05(224)]
}

/// Look up one of the three evaluation models by its graph name at
/// resolution `res` — the single name→builder mapping (CLI parsing, the
/// engine registry, examples and tests all route through it instead of
/// hand-rolling the match).
pub fn by_name(name: &str, res: usize) -> Option<ModelGraph> {
    match name {
        "squeezenet" => Some(squeezenet(res)),
        "mobilenetv2_05" => Some(mobilenetv2_05(res)),
        "shufflenetv2_05" => Some(shufflenetv2_05(res)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn squeezenet_224_shapes() {
        let g = squeezenet(224);
        g.validate().unwrap();
        // stem 7x7/s2 VALID: 224 -> 109; pool -> 54; pools after fire4/fire8
        assert_eq!(g.modules[0].output, TensorShape::new(109, 109, 96));
        assert_eq!(g.modules[1].output, TensorShape::new(54, 54, 96));
        assert_eq!(g.output(), TensorShape::new(1, 1, 1000));
    }

    #[test]
    fn squeezenet_fire_channel_progression() {
        let g = squeezenet(224);
        let fires: Vec<_> = g.modules.iter().filter(|m| m.kind == ModuleKind::Fire).collect();
        assert_eq!(fires.len(), 8);
        assert_eq!(fires[0].input.c, 96);
        assert_eq!(fires[0].output.c, 128);
        assert_eq!(fires[7].output.c, 512);
    }

    #[test]
    fn mobilenetv2_05_224_shapes() {
        let g = mobilenetv2_05(224);
        g.validate().unwrap();
        let bns: Vec<_> = g
            .modules
            .iter()
            .filter(|m| matches!(m.kind, ModuleKind::Bottleneck { .. }))
            .collect();
        assert_eq!(bns.len(), 17);
        // final bottleneck at 7x7x160
        assert_eq!(bns.last().unwrap().output, TensorShape::new(7, 7, 160));
        assert_eq!(g.output(), TensorShape::new(1, 1, 1000));
    }

    #[test]
    fn mobilenetv2_residual_flags() {
        let g = mobilenetv2_05(224);
        for m in &g.modules {
            if let ModuleKind::Bottleneck { residual } = m.kind {
                let expect = m.input == m.output;
                assert_eq!(residual, expect, "{}", m.name);
            }
        }
    }

    #[test]
    fn shufflenetv2_05_224_shapes() {
        let g = shufflenetv2_05(224);
        g.validate().unwrap();
        // stem 112, pool 55, stages at 28/14/7
        assert_eq!(g.modules[1].output.h, 55);
        let reds: Vec<_> = g
            .modules
            .iter()
            .filter(|m| m.kind == ModuleKind::ShuffleReduce)
            .collect();
        assert_eq!(reds.len(), 3);
        assert_eq!(reds[0].output, TensorShape::new(28, 28, 48));
        assert_eq!(reds[2].output, TensorShape::new(7, 7, 192));
        assert_eq!(g.output(), TensorShape::new(1, 1, 1000));
    }

    #[test]
    fn shuffle_basic_preserves_shape() {
        let m = shuffle_basic("b", TensorShape::new(28, 28, 48));
        assert_eq!(m.input, m.output);
        // right branch works on half the channels
        assert_eq!(m.layers[0].input.c, 24);
    }

    #[test]
    fn mac_totals_are_plausible() {
        // Published ballparks (MACs, no BN): SqueezeNet v1.0 ~0.7-0.9G,
        // MNv2 0.5x ~0.1G, SNv2 0.5x ~0.04G.
        let sq = squeezenet(224).macs() as f64;
        let mn = mobilenetv2_05(224).macs() as f64;
        let sn = shufflenetv2_05(224).macs() as f64;
        assert!((0.5e9..1.2e9).contains(&sq), "squeezenet {sq:.3e}");
        assert!((0.6e8..1.5e8).contains(&mn), "mobilenetv2 {mn:.3e}");
        assert!((0.25e8..0.7e8).contains(&sn), "shufflenetv2 {sn:.3e}");
    }

    #[test]
    fn weight_totals_match_python_spec() {
        // python tests assert the same ranges over the L2 spec
        let sq = squeezenet(224).weight_count() as f64;
        let mn = mobilenetv2_05(224).weight_count() as f64;
        let sn = shufflenetv2_05(224).weight_count() as f64;
        assert!((1.1e6..1.4e6).contains(&sq), "squeezenet {sq:.3e}");
        assert!((1.2e6..2.5e6).contains(&mn), "mobilenetv2 {mn:.3e}");
        assert!((0.8e6..1.8e6).contains(&sn), "shufflenetv2 {sn:.3e}");
    }

    #[test]
    fn smaller_resolution_scales_macs_down() {
        let big = squeezenet(224).macs();
        let small = squeezenet(112).macs();
        assert!(small * 3 < big, "{small} vs {big}");
    }
}
