//! CNN graph IR: tensors, ops, layers, modules, models.
//!
//! The paper evaluates at *module* granularity ("mild, layer-wise"
//! partitioning — Table I): a [`Module`] is the unit the partitioner
//! assigns to devices, a [`Layer`] is the unit the device models cost.
//! Shape inference ([`OpKind::infer`]) mirrors the L2 JAX definitions so
//! the Rust cost models and the PJRT artifacts always agree on geometry.

pub mod models;

pub use models::{mobilenetv2_05, shufflenetv2_05, squeezenet, all_models};


/// Spatial feature-map shape (per sample, NHWC without N).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TensorShape {
    pub h: usize,
    pub w: usize,
    pub c: usize,
}

impl TensorShape {
    pub fn new(h: usize, w: usize, c: usize) -> Self {
        Self { h, w, c }
    }

    /// Number of elements.
    pub fn elems(&self) -> usize {
        self.h * self.w * self.c
    }

    /// Size in bytes at the given per-element width.
    pub fn bytes(&self, bytes_per_elem: usize) -> usize {
        self.elems() * bytes_per_elem
    }
}

/// Activation fused into a conv (costless on both devices at this granularity).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Activation {
    None,
    Relu,
    Relu6,
}

/// The operator set used by the paper's three CNNs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpKind {
    /// Standard convolution k x k x Ci x Co.
    Conv { k: usize, stride: usize, pad: usize, cout: usize, act: Activation },
    /// Depth-wise convolution k x k per channel (channel multiplier 1).
    DwConv { k: usize, stride: usize, act: Activation },
    /// Point-wise (1x1) convolution Ci -> Co.
    PwConv { cout: usize, act: Activation },
    /// Grouped convolution: `groups` independent k x k convs (Fig 2b).
    GConv { k: usize, stride: usize, groups: usize, cout: usize, act: Activation },
    /// VALID max pooling.
    MaxPool { k: usize, stride: usize },
    /// Global average pool -> 1x1xC.
    GlobalAvgPool,
    /// ShuffleNet channel shuffle (pure data movement).
    ChannelShuffle { groups: usize },
    /// Concatenate along channels with another branch producing `other_c`.
    Concat { other_c: usize },
    /// Residual add (elementwise).
    Add,
    /// Fully connected C -> cout (final classifier).
    Dense { cout: usize },
}

impl OpKind {
    /// Output shape for a given input shape (mirrors L2 JAX shape rules).
    pub fn infer(&self, i: TensorShape) -> TensorShape {
        fn od(size: usize, k: usize, s: usize, p: usize) -> usize {
            (size + 2 * p - k) / s + 1
        }
        match *self {
            OpKind::Conv { k, stride, pad, cout, .. } => {
                TensorShape::new(od(i.h, k, stride, pad), od(i.w, k, stride, pad), cout)
            }
            OpKind::DwConv { k, stride, .. } => {
                let p = k / 2;
                TensorShape::new(od(i.h, k, stride, p), od(i.w, k, stride, p), i.c)
            }
            OpKind::PwConv { cout, .. } => TensorShape::new(i.h, i.w, cout),
            OpKind::GConv { k, stride, cout, .. } => {
                let p = k / 2;
                TensorShape::new(od(i.h, k, stride, p), od(i.w, k, stride, p), cout)
            }
            OpKind::MaxPool { k, stride } => {
                TensorShape::new(od(i.h, k, stride, 0), od(i.w, k, stride, 0), i.c)
            }
            OpKind::GlobalAvgPool => TensorShape::new(1, 1, i.c),
            OpKind::ChannelShuffle { .. } => i,
            OpKind::Concat { other_c } => TensorShape::new(i.h, i.w, i.c + other_c),
            OpKind::Add => i,
            OpKind::Dense { cout } => TensorShape::new(1, 1, cout),
        }
    }
}

/// One costed operator instance: op + resolved input/output shapes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Layer {
    pub op: OpKind,
    pub input: TensorShape,
    pub output: TensorShape,
}

impl Layer {
    pub fn new(op: OpKind, input: TensorShape) -> Self {
        Self { op, input, output: op.infer(input) }
    }

    /// Multiply-accumulate count (the paper's primary compute measure).
    pub fn macs(&self) -> u64 {
        let o = self.output;
        match self.op {
            OpKind::Conv { k, .. } => (o.elems() * k * k * self.input.c) as u64,
            OpKind::DwConv { k, .. } => (o.elems() * k * k) as u64,
            OpKind::PwConv { .. } => (o.elems() * self.input.c) as u64,
            OpKind::GConv { k, groups, .. } => {
                (o.elems() * k * k * (self.input.c / groups)) as u64
            }
            OpKind::Dense { cout } => (self.input.c * cout) as u64,
            // data movement / pooling: no MACs (pool comparisons ignored)
            _ => 0,
        }
    }

    /// Weight parameter count.
    pub fn weight_count(&self) -> u64 {
        match self.op {
            OpKind::Conv { k, cout, .. } => (k * k * self.input.c * cout) as u64,
            OpKind::DwConv { k, .. } => (k * k * self.input.c) as u64,
            OpKind::PwConv { cout, .. } => (self.input.c * cout) as u64,
            OpKind::GConv { k, groups, cout, .. } => {
                (k * k * (self.input.c / groups) * (cout / groups) * groups) as u64
            }
            OpKind::Dense { cout } => (self.input.c * cout) as u64,
            _ => 0,
        }
    }

    /// True if the op is pure data movement (never dispatched as a kernel).
    pub fn is_data_movement(&self) -> bool {
        matches!(
            self.op,
            OpKind::ChannelShuffle { .. } | OpKind::Concat { .. }
        )
    }
}

/// Module kinds the paper partitions (plus glue).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModuleKind {
    /// Stem conv / final conv / classifier — always GPU-side glue.
    Plain,
    /// SqueezeNet Fire: squeeze1x1 -> {expand1x1 || expand3x3} -> concat.
    Fire,
    /// MobileNetV2 inverted bottleneck: pw-expand -> dw3x3 -> pw-linear (+res).
    Bottleneck { residual: bool },
    /// ShuffleNetV2 basic unit: split -> right(1x1,dw,1x1) -> concat -> shuffle.
    ShuffleBasic,
    /// ShuffleNetV2 reduction unit: two stride-2 branches -> concat -> shuffle.
    ShuffleReduce,
    /// Standalone pooling between modules.
    Pool,
}

/// A named group of layers = the paper's partitioning granularity.
#[derive(Debug, Clone)]
pub struct Module {
    pub name: String,
    pub kind: ModuleKind,
    /// Layers in the role order fixed by `kind` (see partition::roles).
    pub layers: Vec<Layer>,
    pub input: TensorShape,
    pub output: TensorShape,
}

impl Module {
    pub fn macs(&self) -> u64 {
        self.layers.iter().map(Layer::macs).sum()
    }

    pub fn weight_count(&self) -> u64 {
        self.layers.iter().map(Layer::weight_count).sum()
    }
}

/// A whole network: ordered modules with consistent shapes.
#[derive(Debug, Clone)]
pub struct ModelGraph {
    pub name: String,
    pub input: TensorShape,
    pub modules: Vec<Module>,
}

impl ModelGraph {
    pub fn output(&self) -> TensorShape {
        self.modules.last().expect("empty model").output
    }

    pub fn macs(&self) -> u64 {
        self.modules.iter().map(Module::macs).sum()
    }

    pub fn weight_count(&self) -> u64 {
        self.modules.iter().map(Module::weight_count).sum()
    }

    /// Verify inter-module shape consistency (each module consumes its
    /// predecessor's output). Returns the first mismatch.
    pub fn validate(&self) -> Result<(), String> {
        let mut cur = self.input;
        for m in &self.modules {
            if m.input != cur {
                return Err(format!(
                    "{}: module {} expects {:?} but receives {:?}",
                    self.name, m.name, m.input, cur
                ));
            }
            cur = m.output;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ts(h: usize, w: usize, c: usize) -> TensorShape {
        TensorShape::new(h, w, c)
    }

    #[test]
    fn conv_shape_inference_same_pad() {
        let op = OpKind::Conv { k: 3, stride: 1, pad: 1, cout: 8, act: Activation::Relu };
        assert_eq!(op.infer(ts(14, 14, 4)), ts(14, 14, 8));
    }

    #[test]
    fn conv_shape_inference_stride2() {
        let op = OpKind::Conv { k: 3, stride: 2, pad: 1, cout: 8, act: Activation::None };
        assert_eq!(op.infer(ts(224, 224, 3)), ts(112, 112, 8));
    }

    #[test]
    fn conv_shape_inference_valid_7x7s2() {
        // SqueezeNet stem: 224 -> (224-7)/2+1 = 109
        let op = OpKind::Conv { k: 7, stride: 2, pad: 0, cout: 96, act: Activation::Relu };
        assert_eq!(op.infer(ts(224, 224, 3)), ts(109, 109, 96));
    }

    #[test]
    fn maxpool_valid_shape() {
        let op = OpKind::MaxPool { k: 3, stride: 2 };
        assert_eq!(op.infer(ts(109, 109, 96)), ts(54, 54, 96));
    }

    #[test]
    fn dwconv_preserves_channels() {
        let op = OpKind::DwConv { k: 3, stride: 2, act: Activation::Relu6 };
        assert_eq!(op.infer(ts(28, 28, 96)), ts(14, 14, 96));
    }

    #[test]
    fn conv_macs_formula() {
        // 3x3x4 -> 8 over 14x14 SAME: 14*14*8 * 9 * 4
        let l = Layer::new(
            OpKind::Conv { k: 3, stride: 1, pad: 1, cout: 8, act: Activation::None },
            ts(14, 14, 4),
        );
        assert_eq!(l.macs(), 14 * 14 * 8 * 9 * 4);
    }

    #[test]
    fn pwconv_macs_equals_1x1_conv() {
        let i = ts(28, 28, 96);
        let pw = Layer::new(OpKind::PwConv { cout: 16, act: Activation::None }, i);
        let cv = Layer::new(
            OpKind::Conv { k: 1, stride: 1, pad: 0, cout: 16, act: Activation::None },
            i,
        );
        assert_eq!(pw.macs(), cv.macs());
        assert_eq!(pw.weight_count(), cv.weight_count());
    }

    #[test]
    fn gconv_macs_scale_inverse_with_groups() {
        let i = ts(28, 28, 32);
        let g1 = Layer::new(
            OpKind::GConv { k: 3, stride: 1, groups: 1, cout: 32, act: Activation::None },
            i,
        );
        let g4 = Layer::new(
            OpKind::GConv { k: 3, stride: 1, groups: 4, cout: 32, act: Activation::None },
            i,
        );
        assert_eq!(g1.macs(), 4 * g4.macs());
    }

    #[test]
    fn dwconv_macs_equal_gconv_full_groups_modulo_cout() {
        // dw over C channels == gconv with groups=C and cout=C
        let i = ts(14, 14, 24);
        let dw = Layer::new(OpKind::DwConv { k: 3, stride: 1, act: Activation::None }, i);
        let g = Layer::new(
            OpKind::GConv { k: 3, stride: 1, groups: 24, cout: 24, act: Activation::None },
            i,
        );
        assert_eq!(dw.macs(), g.macs());
    }

    #[test]
    fn data_movement_has_no_macs() {
        let i = ts(14, 14, 48);
        for op in [OpKind::ChannelShuffle { groups: 2 }, OpKind::Concat { other_c: 16 }, OpKind::Add] {
            assert_eq!(Layer::new(op, i).macs(), 0);
        }
    }

    #[test]
    fn tensor_bytes() {
        assert_eq!(ts(56, 56, 16).bytes(1), 50176);
        assert_eq!(ts(56, 56, 16).bytes(4), 200704);
    }
}
