//! Bench target for paper Table I: module-level energy gain and latency
//! speedup of the heterogeneous platform, next to the related-work rows
//! the paper quotes, plus family coverage (which instances the resource
//! cliff lets onto the FPGA) and the idle-billing ablation.

use hetero_dnn::experiments;
use hetero_dnn::graph::models;
use hetero_dnn::metrics::Report;
use hetero_dnn::partition::{Planner, Strategy};
use hetero_dnn::sched::{self, IdleParams};

fn main() {
    let planner = Planner::default();
    let dir = std::path::Path::new("target/bench-reports");

    let report = experiments::table1(&planner);
    println!("{}", report.to_text());
    report.write_to(dir, "table1").expect("write report");

    // coverage column (the §III-A resource cliff, quantified)
    let mut cov = Report::new(
        "Table I addendum — family coverage under the DHM resource cliff",
        &["family", "instances_partitioned_%"],
    );
    for (label, c) in experiments::table1_coverage(&planner) {
        cov.row(vec![label.into(), format!("{:.0}", c * 100.0)]);
    }
    println!("{}", cov.to_text());
    cov.write_to(dir, "table1_coverage").expect("write report");

    // ablation: paper methodology vs honest idle billing vs strict
    let mut abl = Report::new(
        "Ablation — energy gain vs idle-billing policy (hetero/gpu-only)",
        &["model", "paper_methodology", "physical_idle", "strict_board_power"],
    );
    for g in models::all_models() {
        let base = planner.plan_model(&g, Strategy::GpuOnly);
        let het = planner.plan_model_paper(&g);
        let gain = |b: f64, h: f64| b / h;
        let paper = gain(
            sched::evaluate_model_with(&base, IdleParams::paper()).total.joules,
            sched::evaluate_model_with(&het, IdleParams::paper()).total.joules,
        );
        let phys = gain(
            sched::evaluate_model_with(&base, IdleParams::default()).total.joules,
            sched::evaluate_model_with(&het, IdleParams::default()).total.joules,
        );
        let strict = gain(
            sched::evaluate_model_strict(&base, IdleParams::default()).total.joules,
            sched::evaluate_model_strict(&het, IdleParams::default()).total.joules,
        );
        abl.row(vec![
            g.name.clone(),
            format!("{paper:.3}x"),
            format!("{phys:.3}x"),
            format!("{strict:.3}x"),
        ]);
    }
    println!("{}", abl.to_text());
    abl.write_to(dir, "table1_ablation").expect("write report");
    println!("wrote target/bench-reports/table1*.{{txt,csv}}");
}
