//! Ablation & sensitivity benches — the design-choice experiments
//! DESIGN.md calls out beyond the paper's own figures:
//!
//!  1. greedy vs DP fabric allocation (optimality gap of the deployed
//!     planner),
//!  2. PCIe bandwidth sensitivity (where is the crossover below which
//!     heterogeneity stops paying? — the paper's §V-B "highly bounded by
//!     the PCIe throughput" caveat, quantified),
//!  3. GPU launch-overhead sensitivity (how much of the gain is really
//!     "the GPU wastes time dispatching small kernels"?),
//!  4. batch-pipelined throughput vs batch size (the deployment view),
//!  5. refined cuDNN-style algorithm selection vs the calibrated base GPU
//!     model (does the refinement change who wins?).

use hetero_dnn::experiments;
use hetero_dnn::graph::models;
use hetero_dnn::gpu::algo::AlgoGpuModel;
use hetero_dnn::link::LinkDevice;
use hetero_dnn::metrics::Report;
use hetero_dnn::partition::{dp, Planner, Strategy};
use hetero_dnn::sched::{self, pipeline, IdleParams};

fn gain(planner: &Planner, g: &hetero_dnn::graph::ModelGraph) -> f64 {
    let base = sched::evaluate_model_with(&planner.plan_model(g, Strategy::GpuOnly), IdleParams::paper());
    let het = sched::evaluate_model_with(&planner.plan_model_paper(g), IdleParams::paper());
    base.total.joules / het.total.joules
}

fn main() {
    let dir = std::path::Path::new("target/bench-reports");
    let planner = Planner::default();

    // ---- 1. greedy vs DP allocation -------------------------------------
    let mut r = Report::new(
        "Ablation 1 — shared-fabric allocation: greedy vs exact DP",
        &["model", "greedy_saving_mJ", "dp_saving_mJ", "gap_%", "dp_cells_used"],
    );
    for g in models::all_models() {
        let greedy = planner.plan_model(&g, Strategy::Auto);
        let alloc = dp::plan_model_dp(&planner, &g);
        let gs = dp::plan_saving(&planner, &g, &greedy) * 1e3;
        let ds = dp::plan_saving(&planner, &g, &alloc.plan) * 1e3;
        let gap = if ds > 0.0 { (1.0 - gs / ds) * 100.0 } else { 0.0 };
        r.row(vec![
            g.name.clone(),
            format!("{gs:.3}"),
            format!("{ds:.3}"),
            format!("{gap:.1}"),
            format!("{}/{}", alloc.cells_used, alloc.cells_total),
        ]);
    }
    println!("{}", r.to_text());
    r.write_to(dir, "ablation_greedy_vs_dp").unwrap();

    // ---- 2. PCIe bandwidth sensitivity (crossover) -----------------------
    let mut r = Report::new(
        "Ablation 2 — energy gain vs PCIe bandwidth (crossover analysis)",
        &["bandwidth_GBps", "squeezenet", "mobilenetv2_05", "shufflenetv2_05"],
    );
    for bw_gbps in [0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0] {
        let mut p = planner;
        p.link.dev = LinkDevice { bandwidth: bw_gbps * 1e9, ..p.link.dev };
        let gains: Vec<String> = models::all_models()
            .iter()
            .map(|g| format!("{:.3}x", gain(&p, g)))
            .collect();
        r.row(vec![format!("{bw_gbps}"), gains[0].clone(), gains[1].clone(), gains[2].clone()]);
    }
    println!("{}", r.to_text());
    r.write_to(dir, "ablation_pcie_bandwidth").unwrap();

    // ---- 3. launch-overhead sensitivity ----------------------------------
    let mut r = Report::new(
        "Ablation 3 — energy gain vs GPU launch overhead",
        &["launch_us", "squeezenet", "mobilenetv2_05", "shufflenetv2_05"],
    );
    for us in [10.0, 50.0, 100.0, 150.0, 300.0, 600.0] {
        let mut p = planner;
        p.gpu.dev.launch_overhead = us * 1e-6;
        let gains: Vec<String> = models::all_models()
            .iter()
            .map(|g| format!("{:.3}x", gain(&p, g)))
            .collect();
        r.row(vec![format!("{us}"), gains[0].clone(), gains[1].clone(), gains[2].clone()]);
    }
    println!("{}", r.to_text());
    r.write_to(dir, "ablation_launch_overhead").unwrap();

    // ---- 4. pipelined throughput vs batch --------------------------------
    let mut r = Report::new(
        "Ablation 4 — batch-pipelined throughput (img/s), hetero vs GPU-only",
        &["model", "batch", "gpu_only_ips", "hetero_ips", "speedup", "bottleneck"],
    );
    for g in models::all_models() {
        let base_plan = planner.plan_model(&g, Strategy::GpuOnly);
        let het_plan = planner.plan_model_paper(&g);
        for n in [1usize, 4, 16, 64] {
            let base = pipeline::evaluate_pipeline(&base_plan, n, IdleParams::paper());
            let het = pipeline::evaluate_pipeline(&het_plan, n, IdleParams::paper());
            r.row(vec![
                g.name.clone(),
                n.to_string(),
                format!("{:.1}", base.throughput),
                format!("{:.1}", het.throughput),
                format!("{:.2}x", het.throughput / base.throughput),
                format!("{:?}", het.bottleneck),
            ]);
        }
    }
    println!("{}", r.to_text());
    r.write_to(dir, "ablation_pipeline").unwrap();

    // ---- 5. base GPU model vs cuDNN-style algorithm selection ------------
    let mut r = Report::new(
        "Ablation 5 — base GPU model vs per-conv algorithm selection",
        &["layer", "base_ms", "algo_ms", "algo"],
    );
    let algo = AlgoGpuModel::default();
    use hetero_dnn::graph::{Activation, Layer, OpKind, TensorShape};
    for (name, l) in [
        ("stem 3x3/s2 224", Layer::new(OpKind::Conv { k: 3, stride: 2, pad: 1, cout: 16, act: Activation::Relu6 }, TensorShape::new(224, 224, 3))),
        ("fire e3 3x3 54", Layer::new(OpKind::Conv { k: 3, stride: 1, pad: 1, cout: 64, act: Activation::Relu }, TensorShape::new(54, 54, 16))),
        ("big 3x3 56x128", Layer::new(OpKind::Conv { k: 3, stride: 1, pad: 1, cout: 128, act: Activation::Relu }, TensorShape::new(56, 56, 128))),
        ("pw 28x96->16", Layer::new(OpKind::PwConv { cout: 16, act: Activation::None }, TensorShape::new(28, 28, 96))),
    ] {
        let base_cost = planner.gpu.cost(&l);
        let (a, ac) = algo.cost(&l);
        r.row(vec![
            name.into(),
            format!("{:.4}", base_cost.ms()),
            format!("{:.4}", ac.ms()),
            format!("{a:?}"),
        ]);
    }
    println!("{}", r.to_text());
    r.write_to(dir, "ablation_gpu_algo").unwrap();
    println!("wrote target/bench-reports/ablation_*.{{txt,csv}}");

    // keep the figure benches honest: verify the reports also regenerate
    let _ = experiments::table1(&planner);
}
