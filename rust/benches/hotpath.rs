//! L3 hot-path micro-benchmarks (custom harness; offline build has no
//! criterion — DESIGN.md §Offline). Measures the pieces that sit on the
//! coordinator's request path:
//!
//!   - device cost models (called per layer per plan)
//!   - module planning (per strategy)
//!   - whole-model planning + timeline evaluation
//!   - artifact execution (simulated fallback when artifacts are missing)
//!   - coordinator round trip across pool sizes (workers 1 vs 4) — batch
//!     formation must not regress when the executor pool widens
//!
//! Each measurement prints mean time per op over a fixed iteration count;
//! the §Perf section of EXPERIMENTS.md records before/after.

use hetero_dnn::coordinator::{Coordinator, CoordinatorConfig};
use hetero_dnn::graph::{models, Activation, Layer, OpKind, TensorShape};
use hetero_dnn::partition::{Planner, Strategy};
use hetero_dnn::runtime::{Runtime, Tensor};
use hetero_dnn::sched;
use std::time::{Duration, Instant};

fn bench<F: FnMut() -> f64>(name: &str, iters: u32, mut f: F) {
    // warmup
    let mut sink = 0.0;
    for _ in 0..iters / 10 + 1 {
        sink += f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        sink += f();
    }
    let per = t0.elapsed() / iters;
    println!("{name:<46} {per:>12?}/iter   (checksum {sink:.3e})");
}

fn main() {
    let planner = Planner::default();
    println!("== L3 hot-path micro-benchmarks ==");

    let conv = Layer::new(
        OpKind::Conv { k: 3, stride: 1, pad: 1, cout: 64, act: Activation::Relu },
        TensorShape::new(56, 56, 64),
    );
    bench("gpu cost model (per layer)", 1_000_000, || planner.gpu.cost(&conv).joules);
    bench("dhm cost model (per layer)", 1_000_000, || {
        planner.dhm.cost(&conv).map(|c| c.joules).unwrap_or(0.0)
    });
    bench("link transfer model", 1_000_000, || {
        planner.link.transfer(56 * 56 * 64, hetero_dnn::link::Precision::Int8).joules
    });

    let fire = models::fire("fire2", TensorShape::new(54, 54, 96), 16, 64, 64);
    bench("plan fire module (gconv-split)", 20_000, || {
        planner
            .plan_gconv_split(&fire)
            .map(|p| sched::evaluate(&p).total.joules)
            .unwrap_or(0.0)
    });

    let sq = models::squeezenet(224);
    bench("plan+evaluate squeezenet (paper)", 2_000, || {
        let plan = planner.plan_model_paper(&sq);
        sched::evaluate_model(&plan).total.joules
    });
    bench("plan+evaluate squeezenet (auto, shared)", 500, || {
        let plan = planner.plan_model(&sq, Strategy::Auto);
        sched::evaluate_model(&plan).total.joules
    });

    // artifact execution (built artifacts when present, simulated otherwise)
    let rt = Runtime::new_or_simulated();
    println!("runtime platform: {}", rt.platform());
    let exe = rt.load("fire_full").expect("load fire_full");
    let inputs = rt.synth_inputs("fire_full", 0).unwrap();
    bench("execute fire_full (56x56x96)", 50, || {
        exe.run(&inputs).unwrap()[0].data[0] as f64
    });
    drop(exe);
    drop(rt);

    // coordinator round trip across pool sizes: batch formation + dispatch
    // overhead must not regress as the executor pool widens
    let mut per_worker_ms: Vec<(usize, f64)> = Vec::new();
    for workers in [1usize, 4] {
        let handle = Coordinator::start(CoordinatorConfig {
            artifact: "fire_full".into(),
            model: "squeezenet".into(),
            strategy: Strategy::Auto,
            max_batch: 8,
            max_wait: Duration::from_micros(100),
            seed: 0,
            admission: None,
            workers,
        })
        .expect("coordinator");
        let coord = handle.coordinator.clone();
        let x = Tensor::randn(coord.input_shape(), 1);
        bench(&format!("coordinator round trip (fire_full, workers={workers})"), 50, || {
            coord.infer(x.clone()).unwrap().output.data[0] as f64
        });
        {
            let m = coord.metrics.lock().unwrap();
            let p50 = m.percentile(0.5) as f64 / 1e3;
            println!(
                "coordinator[workers={workers}]: served {} p50 {:.2} ms p99 {:.2} ms",
                m.served,
                p50,
                m.percentile(0.99) as f64 / 1e3
            );
            per_worker_ms.push((workers, p50));
        }
        drop(coord);
        handle.shutdown();
    }
    if let [(w1, p1), (w4, p4)] = per_worker_ms[..] {
        println!(
            "pool-width check: p50 workers={w1}: {p1:.2} ms vs workers={w4}: {p4:.2} ms \
             ({})",
            if p4 <= p1 * 1.5 { "OK — no batch-formation regression" } else { "REGRESSION?" }
        );
    }
}
