//! L3 hot-path micro-benchmarks (custom harness; offline build has no
//! criterion — DESIGN.md §Offline). Measures the pieces that sit on the
//! engine's request path:
//!
//!   - device cost models (called per layer per plan)
//!   - module planning (per strategy)
//!   - whole-model planning + timeline evaluation
//!   - artifact execution (simulated fallback when artifacts are missing)
//!   - **batch seam**: per-request execution (N independent `run` calls)
//!     vs batch-first execution (`run_batch`, one N-sized call) at
//!     batch >= 4 — the batch path must show lower per-request wall time
//!   - engine round trip across pool sizes (workers 1 vs 4) — batch
//!     formation must not regress when the executor pool widens
//!   - **result cache**: engine round trip on a repeated input with the
//!     content-digest cache on vs off — a hit must beat the full
//!     batcher + backend round trip
//!   - **wire header**: v1 JSON request-header encode+parse vs the v2
//!     fixed-layout binary header (PROTOCOL.md) — v2 must show lower
//!     per-request header overhead
//!   - **pipelining**: N wire round trips through one lockstep v1
//!     connection vs one pipelined v2 `AsyncClient` sustaining 8 in
//!     flight — pipelining must win wall-clock by amortizing the batch
//!     window across in-flight requests
//!   - **hetero serving**: the same engine serving squeezenet on the
//!     heterogeneous device pipeline (paper plan: FPGA/link/GPU lanes
//!     paying simulated service times) vs the single-lane GPU-only
//!     placement — the paper's Table-level hybrid-beats-GPU-only claim,
//!     reproduced at the serving layer (DESIGN.md §10)
//!   - **cluster routing**: a 3-node cluster behind the digest-affinity
//!     router, affinity on vs off over repeated inputs (plus a direct
//!     single node as the floor) — with affinity on, the same input
//!     keeps landing on the node whose result cache holds it, so the
//!     cluster-wide hit count must beat the affinity-off spread
//!     (DESIGN.md §12)
//!   - **slo controller**: the traffic lab's flash-crowd schedule
//!     replayed deterministically (virtual pacing) against a gpu-only
//!     placement with the SLO-driven adaptive controller off vs on — the
//!     controller's hetero flip must strictly lift SLO attainment
//!     (DESIGN.md §13)
//!   - **device contention**: two co-located models on one shared-device
//!     node (DESIGN.md §14), both placed hybrid vs both GPU-only — the
//!     hybrids spread their holds across the arbitrated GPU/FPGA/link
//!     and must beat the GPU-only pair piling onto the one shared GPU
//!   - **trace overhead**: the hetero serving loop with the flight
//!     recorder off vs on (every request traced end to end) — tracing
//!     must stay inside the 5% hot-path overhead contract the recorder
//!     promises (DESIGN.md §15)
//!
//! Each measurement prints mean time per op over a fixed iteration count;
//! the §Perf section of EXPERIMENTS.md records before/after.
//!
//! Flags: `--quick` shrinks every iteration count (CI smoke); `--json`
//! replaces the human verdict lines with one machine-readable JSON line
//! per verdict — `{"name","a_label","a_ns","b_label","b_ns","winner",
//! "ok"}` — so `BENCH_*.json` perf trajectories can be recorded. Human
//! output stays the default.

use hetero_dnn::coordinator::{Completion, EngineBuilder, InferenceRequest, ModelSpec};
use hetero_dnn::graph::{models, Activation, Layer, OpKind, TensorShape};
use hetero_dnn::partition::{Planner, Strategy};
use hetero_dnn::runtime::{Runtime, Tensor};
use hetero_dnn::sched;
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Measure mean wall time per iteration; returns it for verdict lines.
fn measure<F: FnMut() -> f64>(iters: u32, mut f: F) -> (Duration, f64) {
    // warmup
    let mut sink = 0.0;
    for _ in 0..iters / 10 + 1 {
        sink += f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        sink += f();
    }
    (t0.elapsed() / iters, sink)
}

fn bench<F: FnMut() -> f64>(name: &str, iters: u32, f: F) -> Duration {
    let (per, sink) = measure(iters, f);
    println!("{name:<46} {per:>12?}/iter   (checksum {sink:.3e})");
    per
}

/// One comparative verdict: human one-liner by default, one JSON line
/// with `--json` (the bench-smoke CI job validates these parse).
fn verdict(json: bool, name: &str, a: (&str, Duration), b: (&str, Duration), ok: bool, note: &str) {
    let winner = if a.1 <= b.1 { a.0 } else { b.0 };
    if json {
        println!(
            "{{\"name\":\"{name}\",\"a_label\":\"{}\",\"a_ns\":{},\"b_label\":\"{}\",\"b_ns\":{},\
             \"winner\":\"{}\",\"ok\":{}}}",
            a.0,
            a.1.as_nanos(),
            b.0,
            b.1.as_nanos(),
            winner,
            ok
        );
    } else {
        println!(
            "{name} check: {} {:?}/iter vs {} {:?}/iter ({})",
            a.0,
            a.1,
            b.0,
            b.1,
            if ok { note } else { "REGRESSION?" }
        );
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let quick = argv.iter().any(|a| a == "--quick");
    let json = argv.iter().any(|a| a == "--json");
    // quick mode: enough iterations to exercise every path, not to
    // produce stable numbers (the CI smoke job only checks structure)
    let it = |full: u32, q: u32| if quick { q } else { full };

    let planner = Planner::default();
    println!("== L3 hot-path micro-benchmarks{} ==", if quick { " (quick)" } else { "" });

    let conv = Layer::new(
        OpKind::Conv { k: 3, stride: 1, pad: 1, cout: 64, act: Activation::Relu },
        TensorShape::new(56, 56, 64),
    );
    bench("gpu cost model (per layer)", it(1_000_000, 20_000), || planner.gpu.cost(&conv).joules);
    bench("dhm cost model (per layer)", it(1_000_000, 20_000), || {
        planner.dhm.cost(&conv).map(|c| c.joules).unwrap_or(0.0)
    });
    bench("link transfer model", it(1_000_000, 20_000), || {
        planner.link.transfer(56 * 56 * 64, hetero_dnn::link::Precision::Int8).joules
    });

    let fire = models::fire("fire2", TensorShape::new(54, 54, 96), 16, 64, 64);
    bench("plan fire module (gconv-split)", it(20_000, 500), || {
        planner
            .plan_gconv_split(&fire)
            .map(|p| sched::evaluate(&p).total.joules)
            .unwrap_or(0.0)
    });

    let sq = models::squeezenet(224);
    bench("plan+evaluate squeezenet (paper)", it(2_000, 50), || {
        let plan = planner.plan_model_paper(&sq);
        sched::evaluate_model(&plan).total.joules
    });
    bench("plan+evaluate squeezenet (auto, shared)", it(500, 20), || {
        let plan = planner.plan_model(&sq, Strategy::Auto);
        sched::evaluate_model(&plan).total.joules
    });

    // artifact execution (built artifacts when present, simulated otherwise)
    let rt = Runtime::new_or_simulated();
    println!("runtime platform: {}", rt.platform());
    let exe = rt.load("fire_full").expect("load fire_full");
    let inputs = rt.synth_inputs("fire_full", 0).unwrap();
    bench("execute fire_full (56x56x96)", it(50, 10), || {
        exe.run(&inputs).unwrap()[0].data[0] as f64
    });

    // batch seam: the pre-change serving path (per request: borrowed input
    // cloned+hashed into a literal, then its own run_literals dispatch with
    // the pool's pre-converted weights) vs the batch-first worker path
    // (owned inputs MOVE into literals — hash only, no copy — then ONE
    // run_literals_batch call). Owned request tensors are re-created
    // OUTSIDE the timed sections: in serving, that allocation is paid by
    // the client, not the worker.
    const BATCH: usize = 8;
    let seam_iters = it(20, 5) as usize;
    let weights: Vec<Tensor> = inputs[1..].to_vec();
    let weight_lits = exe.prepare(&weights, 1).expect("prepare weights");
    let xs: Vec<Tensor> = (0..BATCH as u64)
        .map(|s| Tensor::randn(&exe.entry.inputs[0].shape, s))
        .collect();
    let mut sink = 0.0f64;
    let (mut old_total, mut new_total) = (Duration::ZERO, Duration::ZERO);
    for _ in 0..seam_iters {
        // old per-request path: clone+hash each borrowed input, N dispatches
        let t = Instant::now();
        for x in &xs {
            let input_lit = exe.prepare(std::slice::from_ref(x), 0).unwrap();
            let mut refs: Vec<&hetero_dnn::runtime::Literal> =
                Vec::with_capacity(1 + weight_lits.len());
            refs.push(&input_lit[0]);
            refs.extend(weight_lits.iter());
            sink += exe.run_literals(&refs).unwrap()[0].data[0] as f64;
        }
        old_total += t.elapsed();

        // batch-first path: inputs move (hash only), one N-sized call
        let owned: Vec<Tensor> = xs.clone();
        let t = Instant::now();
        let input_lits: Vec<hetero_dnn::runtime::Literal> =
            owned.into_iter().map(hetero_dnn::runtime::Literal::from_tensor).collect();
        let elements: Vec<Vec<&hetero_dnn::runtime::Literal>> = input_lits
            .iter()
            .map(|lit| {
                let mut refs = Vec::with_capacity(1 + weight_lits.len());
                refs.push(lit);
                refs.extend(weight_lits.iter());
                refs
            })
            .collect();
        sink += exe.run_literals_batch(&elements).unwrap()[0][0].data[0] as f64;
        new_total += t.elapsed();
    }
    let per_request = old_total / (seam_iters * BATCH) as u32;
    let batch_first = new_total / (seam_iters * BATCH) as u32;
    println!("per-request serving path (fire_full)         {per_request:>12?}/req");
    println!(
        "batch-first serving path (n={BATCH})              {batch_first:>12?}/req  \
         (checksum {sink:.3e})"
    );
    verdict(
        json,
        "batch_first",
        ("batch-first", batch_first),
        ("per-request", per_request),
        batch_first < per_request,
        "OK — batch execution amortizes per-request overhead",
    );
    drop(exe);
    drop(rt);

    // engine round trip across pool sizes: batch formation + dispatch
    // overhead must not regress as the executor pool widens
    let mut per_worker_ms: Vec<(usize, f64)> = Vec::new();
    for workers in [1usize, 4] {
        let handle = EngineBuilder::new()
            .max_batch(8)
            .max_wait(Duration::from_micros(100))
            .model(ModelSpec::new("fire", "fire_full", "squeezenet").workers(workers))
            .build()
            .expect("engine");
        let engine = handle.engine.clone();
        let x = Tensor::randn(&engine.input_shape("fire").expect("registered"), 1);
        bench(&format!("engine round trip (fire_full, workers={workers})"), it(50, 20), || {
            engine.infer(InferenceRequest::new("fire", x.clone())).unwrap().output.data[0] as f64
        });
        {
            let metrics = engine.metrics("fire").expect("registered");
            let m = metrics.lock().unwrap();
            let p50 = m.percentile(0.5) as f64 / 1e3;
            println!(
                "engine[workers={workers}]: served {} p50 {:.2} ms p99 {:.2} ms",
                m.served,
                p50,
                m.percentile(0.99) as f64 / 1e3
            );
            per_worker_ms.push((workers, p50));
        }
        drop(engine);
        handle.shutdown();
    }
    if let [(_, p1), (_, p4)] = per_worker_ms[..] {
        verdict(
            json,
            "pool_width",
            ("workers-4-p50", Duration::from_secs_f64(p4 / 1e3)),
            ("workers-1-p50x1.5", Duration::from_secs_f64(p1 * 1.5 / 1e3)),
            p4 <= p1 * 1.5,
            "OK — no batch-formation regression as the pool widens",
        );
    }

    // result cache: the same input over and over — digest hit at the front
    // door vs the full batcher + worker + backend round trip. The repeated
    // tensor is cloned per call in BOTH arms, so the arms differ only in
    // the serving path.
    let mut cache_per: Vec<(bool, Duration)> = Vec::new();
    for cache_on in [false, true] {
        let mut spec = ModelSpec::new("fire", "fire_full", "squeezenet");
        if cache_on {
            spec = spec.cache(64);
        }
        let handle = EngineBuilder::new()
            .max_wait(Duration::ZERO)
            .model(spec)
            .build()
            .expect("engine");
        let engine = handle.engine.clone();
        let x = Tensor::randn(&engine.input_shape("fire").expect("registered"), 42);
        // warm both arms identically (populates the cache when it is on)
        engine.infer(InferenceRequest::new("fire", x.clone())).expect("warm infer");
        let label = if cache_on { "cache on" } else { "cache off" };
        let per = bench(&format!("engine round trip ({label}, repeat)"), it(100, 30), || {
            engine.infer(InferenceRequest::new("fire", x.clone())).unwrap().output.data[0] as f64
        });
        if cache_on {
            let metrics = engine.metrics("fire").expect("registered");
            let m = metrics.lock().unwrap();
            println!(
                "engine[cache]: {} hits / {} lookups ({:.0}% hit)",
                m.cache_hits,
                m.cache_hits + m.cache_misses,
                m.cache_hit_rate() * 100.0
            );
        }
        cache_per.push((cache_on, per));
        drop(engine);
        handle.shutdown();
    }
    if let [(false, off), (true, on)] = cache_per[..] {
        verdict(
            json,
            "cache",
            ("cache-on", on),
            ("cache-off", off),
            on < off,
            "OK — a digest hit short-circuits the batcher and backend",
        );
    }

    // wire protocol v2 vs v1: per-request header cost (encode + decode),
    // exactly what each side pays per frame before touching the payload
    {
        use hetero_dnn::config::json::{self, Json};
        use hetero_dnn::coordinator::protocol::{self, RequestHeader};

        let dims = vec![1usize, 224, 224, 3];
        let dims_v1 = dims.clone();
        let v1_per = bench("wire header v1 (JSON encode+parse)", it(100_000, 2_000), move || {
            let hdr = format!(
                "{{\"id\":42,\"model\":\"squeezenet\",\"priority\":\"high\",\"deadline_us\":2000,\"shape\":[{}]}}",
                dims_v1.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(",")
            );
            let h = json::parse(&hdr).expect("v1 header parses");
            let id = h.get("id").and_then(Json::as_usize).expect("id");
            let shape: Vec<usize> = h
                .get("shape")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_usize).collect())
                .expect("shape");
            (id + shape.iter().product::<usize>()) as f64
        });
        let v2_per = bench("wire header v2 (binary encode+decode)", it(100_000, 2_000), move || {
            let h = RequestHeader {
                id: 42,
                model: 0,
                priority: 1,
                deadline_us: 2_000,
                dims: dims.clone(),
            };
            let buf = protocol::encode_request_header(&h);
            let (back, _) = protocol::decode_request_header(&buf).expect("v2 header decodes");
            (back.id as usize + back.dims.iter().product::<usize>()) as f64
        });
        verdict(
            json,
            "wire_header",
            ("v2-binary", v2_per),
            ("v1-json", v1_per),
            v2_per < v1_per,
            "OK — the fixed-layout header cuts per-request overhead",
        );
    }

    // pipelining: the same engine + TCP server driven by one lockstep v1
    // connection vs one pipelined v2 connection holding 8 in flight
    {
        use hetero_dnn::coordinator::protocol::{AsyncClient, Reply};
        use hetero_dnn::coordinator::server::{Client, Server};

        let wire_reqs = it(48, 16) as usize;
        const DEPTH: usize = 8;
        let handle = EngineBuilder::new()
            .max_batch(8)
            .max_wait(Duration::from_micros(500))
            .model(ModelSpec::new("fire", "fire_full", "squeezenet").workers(2))
            .build()
            .expect("engine");
        let engine = handle.engine.clone();
        let server = Server::start("127.0.0.1:0", engine.clone()).expect("server");
        let shape = engine.input_shape("fire").expect("registered");
        let xs: Vec<Tensor> = (0..wire_reqs as u64).map(|s| Tensor::randn(&shape, s)).collect();

        let mut v1 = Client::connect(&server.addr).expect("v1 connect");
        let t = Instant::now();
        for x in &xs {
            v1.infer(x).expect("v1 infer");
        }
        let lockstep = t.elapsed();

        let mut v2 = AsyncClient::connect(&server.addr).expect("v2 connect");
        let t = Instant::now();
        let (mut submitted, mut received, mut peak) = (0usize, 0usize, 0usize);
        while received < wire_reqs {
            while submitted < wire_reqs && v2.in_flight() < DEPTH {
                v2.submit(None, &xs[submitted]).expect("submit");
                submitted += 1;
            }
            peak = peak.max(v2.in_flight());
            match v2.recv().expect("recv") {
                Reply::Response(_) => received += 1,
                Reply::Error { code, message, .. } => panic!("{code}: {message}"),
            }
        }
        let pipelined = t.elapsed();
        println!(
            "wire round trips (n={wire_reqs})            lockstep v1 {lockstep:>10?} | \
             pipelined v2 {pipelined:>10?} (peak {peak} in flight)"
        );
        verdict(
            json,
            "pipelining",
            ("pipelined-v2", pipelined / wire_reqs as u32),
            ("lockstep-v1", lockstep / wire_reqs as u32),
            pipelined < lockstep && peak >= DEPTH,
            "OK — in-flight requests fill batches the lockstep client leaves empty",
        );
        server.stop();
        drop(engine);
        handle.shutdown();
    }

    // hetero serving: squeezenet on the heterogeneous device pipeline
    // (paper plan: FPGA → link → GPU lanes billing the simulated
    // platform's service times) vs the single-lane GPU-only placement.
    // Both placements pay simulated device time, so the wall-clock ratio
    // IS the paper's hybrid-vs-GPU-only serving-throughput claim.
    {
        let images = it(48, 16) as usize;
        const DEPTH: usize = 6;
        let mut walls: Vec<(&str, Duration)> = Vec::new();
        for (label, strat) in [("gpu-only", Strategy::GpuOnly), ("hybrid", Strategy::Paper)] {
            let handle = EngineBuilder::new()
                .max_batch(4)
                .max_wait(Duration::ZERO)
                .model(ModelSpec::net("squeezenet").placement(strat))
                .build()
                .expect("engine");
            let engine = handle.engine.clone();
            let shape = engine.input_shape("squeezenet").expect("registered");
            let xs: Vec<Tensor> = (0..images as u64).map(|s| Tensor::randn(&shape, s)).collect();
            // warm the lanes (runtime + weights are set up at build, but
            // let one image flow through before the stopwatch starts)
            engine
                .infer(InferenceRequest::new("squeezenet", xs[0].clone()))
                .expect("warm infer");
            let (sink_tx, done) = mpsc::channel::<Completion>();
            let t = Instant::now();
            let (mut submitted, mut received, mut in_flight) = (0usize, 0usize, 0usize);
            while received < images {
                while submitted < images && in_flight < DEPTH {
                    let req = InferenceRequest::new("squeezenet", xs[submitted].clone());
                    engine.submit(req, submitted as u64, &sink_tx).expect("submit");
                    submitted += 1;
                    in_flight += 1;
                }
                done.recv().expect("completion").result.expect("infer ok");
                received += 1;
                in_flight -= 1;
            }
            let wall = t.elapsed();
            print!(
                "hetero serving [{label:<8}] {images} images in {wall:>10?} ({:>6.0} img/s)",
                images as f64 / wall.as_secs_f64()
            );
            if let Some(dm) = engine.device_metrics("squeezenet") {
                let (bottleneck, _) = dm.busiest();
                print!(
                    "   lanes: gpu {:.1} ms sim | fpga {:.1} ms | link {:.1} ms, {:.2} MB | \
                     bottleneck {bottleneck}",
                    dm.gpu.sim_busy().as_secs_f64() * 1e3,
                    dm.fpga.sim_busy().as_secs_f64() * 1e3,
                    dm.link.sim_busy().as_secs_f64() * 1e3,
                    dm.transferred_bytes() as f64 / 1e6,
                );
            }
            println!();
            walls.push((label, wall / images as u32));
            drop(engine);
            handle.shutdown();
        }
        if let [(gl, gpu_only), (hl, hybrid)] = walls[..] {
            verdict(
                json,
                "hetero_serving",
                (hl, hybrid),
                (gl, gpu_only),
                hybrid < gpu_only,
                "OK — hybrid-pipelined serving outruns GPU-only, PCIe cost included",
            );
        }
    }

    // cluster routing: K distinct inputs cycled for several rounds
    // against a 3-node cluster behind the router. With digest affinity
    // every input rendezvous-hashes back to the node whose result cache
    // holds it; with affinity off the load tie-rotation spreads the same
    // input across nodes and the per-node caches keep missing. A direct
    // single-node client gives the no-router floor.
    {
        use hetero_dnn::cluster::{Node, Router, RouterConfig, Topology};
        use hetero_dnn::coordinator::protocol::{AsyncClient, Reply};

        let rounds = it(6, 3) as usize;
        const K: usize = 4;
        const NODES: usize = 3;
        let spec = || ModelSpec::new("fire", "fire_full", "squeezenet").workers(1).cache(32);

        let mut direct_node = Node::start(vec![spec()]).expect("direct node");
        let mut direct = AsyncClient::connect(&direct_node.addr()).expect("direct connect");
        let shape = direct.models()[0].1.clone();
        let xs: Vec<Tensor> = (0..K as u64).map(|s| Tensor::randn(&shape, s)).collect();
        let run = |client: &mut AsyncClient| -> Duration {
            let t = Instant::now();
            for _ in 0..rounds {
                for x in &xs {
                    client.submit(None, x).expect("submit");
                }
                for _ in 0..K {
                    match client.recv().expect("recv") {
                        Reply::Response(_) => {}
                        Reply::Error { code, message, .. } => panic!("{code}: {message}"),
                    }
                }
            }
            t.elapsed()
        };
        let total = (rounds * K) as u32;

        let direct_wall = run(&mut direct);
        drop(direct);
        direct_node.kill();
        println!(
            "cluster routing [direct, 1 node ] {total} reqs in {direct_wall:>10?} \
             ({:>10?}/req)",
            direct_wall / total
        );

        let mut arms: Vec<(bool, Duration, u64)> = Vec::new();
        for affinity in [false, true] {
            let topo = Topology::new();
            for _ in 0..NODES {
                topo.add(Node::start(vec![spec()]).expect("cluster node"));
            }
            let cfg = RouterConfig { affinity, ..RouterConfig::default() };
            let router = Router::start("127.0.0.1:0", &topo.addrs(), cfg).expect("router");
            let mut client = AsyncClient::connect(&router.addr).expect("router connect");
            let wall = run(&mut client);
            drop(client);
            let mut hits = 0u64;
            for i in 0..NODES {
                let engine = topo.engine(i).expect("alive");
                let metrics = engine.metrics("fire").expect("registered");
                hits += metrics.lock().unwrap().cache_hits;
            }
            println!(
                "cluster routing [affinity {:<3}, {NODES} nodes] {total} reqs in {wall:>10?} \
                 ({:>10?}/req, {hits} cache hits)",
                if affinity { "on" } else { "off" },
                wall / total
            );
            arms.push((affinity, wall, hits));
            router.stop();
        }
        if let [(false, wall_off, hits_off), (true, wall_on, hits_on)] = arms[..] {
            verdict(
                json,
                "cluster_routing",
                ("affinity-on", wall_on / total),
                ("affinity-off", wall_off / total),
                hits_on > hits_off,
                "OK — digest affinity keeps repeat inputs on the node that cached them",
            );
        }
    }

    // slo controller: the flash-crowd schedule replayed deterministically
    // (virtual pacing, DESIGN.md §13) against a gpu-only pool placement,
    // adaptive controller off vs on. The SLO sits between the gpu-only
    // and paper-plan simulated latencies, so only the controller's hetero
    // flip can meet it — flash-crowd SLO attainment must strictly improve
    // with the controller on.
    {
        use hetero_dnn::workloads::{
            build_schedule, replay_engine, ControllerConfig, ReplayConfig, ScenarioSpec,
        };

        let sim_us = |strategy: Strategy| {
            let plan = planner.plan_model(&sq, strategy);
            (sched::evaluate_model(&plan).total.seconds * 1e6).round() as u64
        };
        let slow = sim_us(Strategy::GpuOnly);
        let fast = sim_us(Strategy::Paper);
        let slo = (fast + slow) / 2;
        let spec = ScenarioSpec::named("flash_crowd").expect("registered scenario");
        let schedule = build_schedule(&spec, 1, 8, Duration::from_millis(u64::from(it(400, 150))));
        let mut arms: Vec<(bool, f64, Duration)> = Vec::new();
        for controller_on in [false, true] {
            let handle = EngineBuilder::new()
                .max_wait(Duration::ZERO)
                .model(
                    ModelSpec::new("squeeze", "fire_full", "squeezenet")
                        .strategy(Strategy::GpuOnly),
                )
                .build()
                .expect("engine");
            let engine = handle.engine.clone();
            let cfg = ReplayConfig {
                slo_p99_us: slo,
                controller: controller_on.then(|| ControllerConfig {
                    slo_p99_us: slo,
                    clear_ticks: 1_000,
                    hysteresis: Duration::from_millis(200),
                    ..ControllerConfig::default()
                }),
                ..ReplayConfig::default()
            };
            let report = replay_engine(&engine, &schedule, &cfg);
            println!("slo controller [{}] {report}", if controller_on { "on " } else { "off" });
            arms.push((controller_on, report.attainment(), Duration::from_micros(report.p99_us)));
            drop(engine);
            handle.shutdown();
        }
        if let [(false, att_off, p99_off), (true, att_on, p99_on)] = arms[..] {
            verdict(
                json,
                "slo_controller",
                ("controller-on-p99", p99_on),
                ("controller-off-p99", p99_off),
                att_on > att_off,
                "OK — the adaptive flip meets the SLO the static placement cannot",
            );
        }
    }

    // device contention: two co-located models on one shared-device node
    // (DESIGN.md §14). Hybrid placements spread their holds across the
    // arbitrated GPU/FPGA/link, while two GPU-only placements pile every
    // hold onto the one shared GPU — co-located hybrids must win wall
    // clock: the paper's heterogeneity claim restated under multi-tenant
    // contention.
    {
        let images = it(32, 12) as usize; // per tenant
        const DEPTH: usize = 4;
        const TENANTS: [&str; 2] = ["squeezenet", "shufflenetv2_05"];
        let mut walls: Vec<(&str, Duration)> = Vec::new();
        let arms = [("dual-gpu-only", Strategy::GpuOnly), ("dual-hybrid", Strategy::Paper)];
        for (label, strat) in arms {
            let mut b = EngineBuilder::new().shared_devices().max_batch(4).max_wait(Duration::ZERO);
            for net in TENANTS {
                b = b.model(ModelSpec::net(net).placement(strat));
            }
            let handle = b.build().expect("engine");
            let engine = handle.engine.clone();
            let mut inputs = Vec::new();
            for net in TENANTS {
                let shape = engine.input_shape(net).expect("registered");
                let xs: Vec<Tensor> =
                    (0..images as u64).map(|s| Tensor::randn(&shape, s)).collect();
                engine.infer(InferenceRequest::new(net, xs[0].clone())).expect("warm");
                inputs.push(xs);
            }
            let (sink_tx, done) = mpsc::channel::<Completion>();
            let total = images * TENANTS.len();
            let t = Instant::now();
            let (mut submitted, mut received, mut in_flight) = (0usize, 0usize, 0usize);
            while received < total {
                while submitted < total && in_flight < DEPTH {
                    // interleave the tenants so both contend the whole run
                    let (tenant, img) = (submitted % TENANTS.len(), submitted / TENANTS.len());
                    let req = InferenceRequest::new(TENANTS[tenant], inputs[tenant][img].clone());
                    engine.submit(req, submitted as u64, &sink_tx).expect("submit");
                    submitted += 1;
                    in_flight += 1;
                }
                done.recv().expect("completion").result.expect("infer ok");
                received += 1;
                in_flight -= 1;
            }
            let wall = t.elapsed();
            let node = engine.node_device_metrics().expect("shared node");
            let (hot, held) = node.most_contended();
            println!(
                "device contention [{label:<13}] {total} images in {wall:>10?} \
                 ({:>6.0} img/s, hot device {hot} held {:.1} ms)",
                total as f64 / wall.as_secs_f64(),
                held.as_secs_f64() * 1e3,
            );
            walls.push((label, wall / total as u32));
            drop(engine);
            handle.shutdown();
        }
        if let [(gl, gpu_only), (hl, hybrid)] = walls[..] {
            verdict(
                json,
                "device_contention",
                (hl, hybrid),
                (gl, gpu_only),
                hybrid < gpu_only,
                "OK — co-located hybrids beat co-located GPU-only on shared devices",
            );
        }
    }

    // trace overhead: the hetero serving loop again, flight recorder off
    // vs on (every request traced admission → device lanes → reply).
    // The recorder's hot-path contract (DESIGN.md §15) is "never block,
    // never allocate on the emit path beyond the ring slot": per-image
    // wall time with tracing on must stay within 5% of tracing off.
    {
        let images = it(48, 16) as usize;
        const DEPTH: usize = 6;
        let mut walls: Vec<(&str, Duration)> = Vec::new();
        for (label, tracing) in [("tracing-off", false), ("tracing-on", true)] {
            let mut b = EngineBuilder::new()
                .max_batch(4)
                .max_wait(Duration::ZERO)
                .model(ModelSpec::net("squeezenet").placement(Strategy::Paper));
            if tracing {
                b = b.tracing();
            }
            let handle = b.build().expect("engine");
            let engine = handle.engine.clone();
            let shape = engine.input_shape("squeezenet").expect("registered");
            let xs: Vec<Tensor> = (0..images as u64).map(|s| Tensor::randn(&shape, s)).collect();
            engine
                .infer(InferenceRequest::new("squeezenet", xs[0].clone()))
                .expect("warm infer");
            let (sink_tx, done) = mpsc::channel::<Completion>();
            let t = Instant::now();
            let (mut submitted, mut received, mut in_flight) = (0usize, 0usize, 0usize);
            while received < images {
                while submitted < images && in_flight < DEPTH {
                    let req = InferenceRequest::new("squeezenet", xs[submitted].clone());
                    engine.submit(req, submitted as u64, &sink_tx).expect("submit");
                    submitted += 1;
                    in_flight += 1;
                }
                done.recv().expect("completion").result.expect("infer ok");
                received += 1;
                in_flight -= 1;
            }
            let wall = t.elapsed();
            print!(
                "trace overhead [{label:<11}] {images} images in {wall:>10?} ({:>6.0} img/s)",
                images as f64 / wall.as_secs_f64()
            );
            if tracing {
                let snap = engine.trace_snapshot().expect("recorder on");
                print!(
                    "   {} events on {} tracks, {} span chains, {} dropped",
                    snap.events.len(),
                    snap.tracks.len(),
                    snap.chains().len(),
                    snap.dropped
                );
            }
            println!();
            walls.push((label, wall / images as u32));
            drop(engine);
            handle.shutdown();
        }
        if let [(ol, off), (nl, on)] = walls[..] {
            // the 5% contract, plus a 50us absolute floor so quick-mode
            // jitter over a handful of images cannot flake the CI check
            let bound = off + off / 20 + Duration::from_micros(50);
            verdict(
                json,
                "trace_overhead",
                (nl, on),
                (ol, off),
                on < bound,
                "OK — end-to-end tracing stays inside the 5% overhead contract",
            );
        }
    }
}
