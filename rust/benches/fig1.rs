//! Bench target for paper Fig 1 (a: latency, b: energy).
//!
//! Regenerates both subfigures' series — FPGA-DHM vs GPU for convolutions
//! on a 224x224x3 input across kernel sizes {1,3,5} and filter counts
//! {2..64} — prints the paper-style rows, writes the CSV twin under
//! `target/bench-reports/`, and times the harness itself (the L3 hot path
//! is the cost model; it must stay micro-second fast for the Auto planner).

use hetero_dnn::experiments;
use hetero_dnn::partition::Planner;
use std::time::Instant;

fn main() {
    let planner = Planner::default();

    // correctness: the figure itself
    let report = experiments::fig1(&planner);
    println!("{}", report.to_text());

    let pts = experiments::fig1_points(&planner);
    let fits = pts.iter().filter(|p| p.fpga.is_some()).count();
    println!("DHM-mappable points: {fits}/{}", pts.len());
    let worst = pts
        .iter()
        .filter_map(|p| p.fpga.map(|f| (p.k, p.n, p.gpu.joules / f.joules)))
        .min_by(|a, b| a.2.partial_cmp(&b.2).unwrap())
        .unwrap();
    let best = pts
        .iter()
        .filter_map(|p| p.fpga.map(|f| (p.k, p.n, p.gpu.joules / f.joules)))
        .max_by(|a, b| a.2.partial_cmp(&b.2).unwrap())
        .unwrap();
    println!("energy ratio range: {:.1}x (k{} n{}) .. {:.1}x (k{} n{})",
             worst.2, worst.0, worst.1, best.2, best.0, best.1);

    // perf: cost-model throughput (L3 hot-path building block)
    let iters = 2000;
    let t0 = Instant::now();
    let mut sink = 0.0f64;
    for _ in 0..iters {
        for p in experiments::fig1_points(&planner) {
            sink += p.gpu.joules + p.fpga.map(|f| f.joules).unwrap_or(0.0);
        }
    }
    let dt = t0.elapsed();
    let per_sweep = dt / iters;
    println!(
        "harness: {iters} full sweeps in {dt:?} ({per_sweep:?}/sweep, {:.1} ns/point, checksum {sink:.3})",
        per_sweep.as_nanos() as f64 / pts.len() as f64
    );

    let dir = std::path::Path::new("target/bench-reports");
    report.write_to(dir, "fig1").expect("write report");
    println!("wrote target/bench-reports/fig1.{{txt,csv}}");
}
