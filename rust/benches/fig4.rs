//! Bench target for paper Fig 4 (a: SqueezeNet, b: MobileNetV2-0.5,
//! c: ShuffleNetV2-0.5): per-module energy/latency of the GPU-only vs the
//! heterogeneous platform across the paper's IFM scales, plus the
//! whole-net planning throughput (the L3 hot path: plan + schedule a full
//! model).

use hetero_dnn::experiments;
use hetero_dnn::graph::models;
use hetero_dnn::partition::{Planner, Strategy};
use hetero_dnn::sched;
use std::time::Instant;

fn main() {
    let planner = Planner::default();
    let dir = std::path::Path::new("target/bench-reports");

    for (sub, model) in [("a", "squeezenet"), ("b", "mobilenetv2_05"), ("c", "shufflenetv2_05")] {
        let report = experiments::fig4(&planner, model);
        println!("{}", report.to_text());
        report.write_to(dir, &format!("fig4{sub}")).expect("write report");
    }
    println!("wrote target/bench-reports/fig4{{a,b,c}}.{{txt,csv}}");

    // perf: full-model plan+evaluate throughput (paper-methodology planner)
    for g in models::all_models() {
        let iters = 200;
        let t0 = Instant::now();
        let mut sink = 0.0;
        for _ in 0..iters {
            let plan = planner.plan_model_paper(&g);
            sink += sched::evaluate_model(&plan).total.joules;
        }
        let per = t0.elapsed() / iters;
        println!("plan_model_paper({}): {per:?}/iter (checksum {sink:.3})", g.name);

        let t0 = Instant::now();
        let mut sink2 = 0.0;
        for _ in 0..iters {
            let plan = planner.plan_model(&g, Strategy::Auto);
            sink2 += sched::evaluate_model(&plan).total.joules;
        }
        let per = t0.elapsed() / iters;
        println!("plan_model(auto, shared fabric)({}): {per:?}/iter (checksum {sink2:.3})", g.name);
    }
}
