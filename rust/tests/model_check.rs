//! Model-check suite: run every core scenario under the CI quick
//! profile and hold the coverage floor — each scenario must explore at
//! least 1000 distinct complete schedules with every invariant green —
//! plus the checker's own regression: a seeded bug must be found and its
//! printed schedule must replay to the identical violation.
//!
//! This is what CI's `model-check` job runs (`cargo test --release
//! --test model_check`). On a violation the test prints the numbered
//! schedule from the [`hetero_dnn::check::Violation`] display — paste
//! those action names into `Checker::replay` (DESIGN.md §11) to
//! reproduce it under a debugger.

use hetero_dnn::check::scenarios;
use hetero_dnn::check::{Profile, Report};

/// The coverage floor each scenario must clear under the quick profile.
const MIN_SCHEDULES: usize = 1000;

fn assert_coverage(name: &str, report: Report) {
    assert!(
        report.completed >= MIN_SCHEDULES,
        "{name}: only {} complete schedules explored (need >= {MIN_SCHEDULES}); \
         deepest schedule {} steps",
        report.completed,
        report.deepest,
    );
}

#[test]
fn reply_exactly_once_holds_under_quick_profile() {
    let report = scenarios::reply_exactly_once(Profile::quick())
        .unwrap_or_else(|v| panic!("reply_exactly_once violated:\n{v}"));
    assert_coverage("reply_exactly_once", report);
}

#[test]
fn slot_exactly_once_holds_under_quick_profile() {
    let report = scenarios::slot_exactly_once(Profile::quick())
        .unwrap_or_else(|v| panic!("slot_exactly_once violated:\n{v}"));
    assert_coverage("slot_exactly_once", report);
}

#[test]
fn drain_empties_queues_holds_under_quick_profile() {
    let report = scenarios::drain_empties_queues(Profile::quick())
        .unwrap_or_else(|v| panic!("drain_empties_queues violated:\n{v}"));
    assert_coverage("drain_empties_queues", report);
}

#[test]
fn backpressure_no_deadlock_holds_under_quick_profile() {
    let report = scenarios::backpressure_no_deadlock(Profile::quick())
        .unwrap_or_else(|v| panic!("backpressure_no_deadlock violated:\n{v}"));
    assert_coverage("backpressure_no_deadlock", report);
}

#[test]
fn hot_swap_linearized_holds_under_quick_profile() {
    let report = scenarios::hot_swap_linearized(Profile::quick())
        .unwrap_or_else(|v| panic!("hot_swap_linearized violated:\n{v}"));
    assert_coverage("hot_swap_linearized", report);
}

#[test]
fn router_failover_exactly_once_holds_under_quick_profile() {
    let report = scenarios::router_failover_exactly_once(Profile::quick())
        .unwrap_or_else(|v| panic!("router_failover_exactly_once violated:\n{v}"));
    assert_coverage("router_failover_exactly_once", report);
}

#[test]
fn controller_actions_linearized_holds_under_quick_profile() {
    let report = scenarios::controller_actions_linearized(Profile::quick())
        .unwrap_or_else(|v| panic!("controller_actions_linearized violated:\n{v}"));
    assert_coverage("controller_actions_linearized", report);
}

#[test]
fn arbiter_grants_exactly_once_holds_under_quick_profile() {
    let report = scenarios::arbiter_grants_exactly_once(Profile::quick())
        .unwrap_or_else(|v| panic!("arbiter_grants_exactly_once violated:\n{v}"));
    assert_coverage("arbiter_grants_exactly_once", report);
}

#[test]
fn trace_spans_well_nested_holds_under_quick_profile() {
    let report = scenarios::trace_spans_well_nested(Profile::quick())
        .unwrap_or_else(|v| panic!("trace_spans_well_nested violated:\n{v}"));
    assert_coverage("trace_spans_well_nested", report);
}

/// The checker itself is under test here: the seeded double-reply bug
/// must be caught, carry a non-empty schedule, and — replayed from the
/// schedule names alone, the way a developer would paste them from the
/// failure output — reproduce the identical violation.
#[test]
fn seeded_bug_is_caught_and_schedule_replays_identically() {
    let (found, replayed) = scenarios::buggy_double_reply(Profile::quick());
    assert_eq!(found.invariant, "reply at-most-once");
    assert!(!found.schedule.is_empty(), "violation must carry its schedule");
    assert_eq!(replayed.invariant, found.invariant, "replay diverged:\n{replayed}");
    assert_eq!(replayed.detail, found.detail, "replay diverged:\n{replayed}");
    assert_eq!(replayed.schedule, found.schedule, "replay diverged:\n{replayed}");

    // the display output is the reproduction recipe: it must name the
    // invariant and number every step
    let printed = found.to_string();
    assert!(printed.contains("reply at-most-once"), "{printed}");
    assert!(printed.contains("replayable"), "{printed}");
    for name in &found.schedule {
        assert!(printed.contains(name), "schedule step {name} missing from display");
    }
}
