//! Integration tests: the serving engine end to end (per-model executor
//! pools, dynamic batcher, metrics, TCP front end).
//!
//! Two tiers:
//! - the **worker-pool suite** runs unconditionally: without built
//!   artifacts the engine falls back to the simulated platform runtime,
//!   which is deterministic — so batching, pool dispatch, shutdown
//!   ordering and the wire protocol are fully testable in CI;
//! - the **artifact suite** additionally requires `make artifacts` and is
//!   skipped otherwise (it pins the real fire_full geometry).
//!
//! Multi-model and batch-equivalence coverage lives in
//! `integration_engine.rs`; the ISSUE 3 serving scenarios (result cache,
//! per-model budgets, hot-swap) in `integration_serving_scenarios.rs`.

use hetero_dnn::config::Manifest;
use hetero_dnn::coordinator::server::{Client, Server};
use hetero_dnn::coordinator::{EngineBuilder, EngineHandle, InferenceRequest, ModelSpec};
use hetero_dnn::runtime::Tensor;
use std::time::Duration;

fn artifacts_built() -> bool {
    Manifest::load().is_ok()
}

/// Serve the small fire module artifact — fast enough for CI.
fn fire_builder(workers: usize) -> EngineBuilder {
    EngineBuilder::new()
        .max_batch(4)
        .max_wait(Duration::from_millis(1))
        .model(ModelSpec::new("fire", "fire_full", "squeezenet").workers(workers))
}

fn fire_engine(workers: usize) -> EngineHandle {
    fire_builder(workers).build().expect("engine")
}

fn infer_fire(
    engine: &hetero_dnn::coordinator::Engine,
    x: Tensor,
) -> Result<hetero_dnn::coordinator::InferenceResponse, hetero_dnn::runtime::RuntimeError> {
    engine.infer(InferenceRequest::new("fire", x))
}

// ===========================================================================
// worker-pool suite (runs with or without built artifacts)

#[test]
fn worker_pool_completes_all_requests_identically_across_pool_sizes() {
    // N clients x M requests must all complete for workers in {1, 4}, and
    // the (deterministic) results must not depend on the pool size or on
    // which worker served a request.
    const CLIENTS: u64 = 4;
    const PER_CLIENT: u64 = 3;
    let inputs: Vec<Tensor> = (0..CLIENTS * PER_CLIENT)
        .map(|i| Tensor::randn(&[1, 56, 56, 96], 1000 + i))
        .collect();

    let mut all_outputs: Vec<Vec<Tensor>> = Vec::new();
    for workers in [1usize, 4] {
        let handle = fire_engine(workers);
        let engine = handle.engine.clone();
        assert_eq!(engine.workers("fire"), Some(workers));
        assert_eq!(engine.input_shape("fire"), Some(vec![1, 56, 56, 96]));
        assert_eq!(engine.models(), vec!["fire"]);

        let mut joins = Vec::new();
        for c in 0..CLIENTS {
            let engine = engine.clone();
            let inputs = inputs.clone();
            joins.push(std::thread::spawn(move || {
                (0..PER_CLIENT)
                    .map(|i| {
                        let x = inputs[(c * PER_CLIENT + i) as usize].clone();
                        let r = infer_fire(&engine, x).expect("infer");
                        assert_eq!(r.output.shape, vec![1, 56, 56, 128]);
                        assert!(r.output.data.iter().all(|v| v.is_finite()));
                        assert!(r.worker < workers);
                        assert_eq!(r.model, "fire");
                        assert!(r.batch_index < r.batch_size);
                        r.output
                    })
                    .collect::<Vec<Tensor>>()
            }));
        }
        let mut outputs = Vec::new();
        for j in joins {
            outputs.extend(j.join().unwrap());
        }
        assert_eq!(outputs.len(), (CLIENTS * PER_CLIENT) as usize);
        let metrics = engine.metrics("fire").expect("registered");
        assert_eq!(metrics.lock().unwrap().served, CLIENTS * PER_CLIENT);
        all_outputs.push(outputs);
        drop(engine);
        handle.shutdown();
    }

    for (a, b) in all_outputs[0].iter().zip(&all_outputs[1]) {
        assert_eq!(a.max_abs_diff(b), 0.0, "results must not depend on pool size");
    }
}

#[test]
fn worker_pool_spreads_load_across_workers() {
    // sustained concurrent load with batch-of-1 dispatch: while one worker
    // is busy its in-flight count is non-zero, so least-loaded dispatch
    // must route to a different worker — over 32 requests from 4 clients
    // the pool must be observably shared
    let handle = fire_builder(4).max_batch(1).max_wait(Duration::ZERO).build().expect("engine");
    let engine = handle.engine.clone();
    let mut joins = Vec::new();
    for c in 0..4u64 {
        let engine = engine.clone();
        joins.push(std::thread::spawn(move || {
            (0..8u64)
                .map(|i| {
                    infer_fire(&engine, Tensor::randn(&[1, 56, 56, 96], c * 8 + i))
                        .expect("infer")
                        .worker
                })
                .collect::<Vec<usize>>()
        }));
    }
    let workers_hit: std::collections::BTreeSet<usize> =
        joins.into_iter().flat_map(|j| j.join().unwrap()).collect();
    assert!(workers_hit.iter().all(|&w| w < 4));
    assert!(
        workers_hit.len() > 1,
        "least-loaded dispatch routed all 32 concurrent requests to one worker: {workers_hit:?}"
    );
    drop(engine);
    handle.shutdown();
}

#[test]
fn shutdown_with_requests_queued_answers_everything() {
    // a long batching window keeps requests sitting in the batcher; a
    // shutdown racing them must leave every client with a definite answer
    // (success or a clean serving error) — never a hang or a panic
    let handle = fire_builder(2)
        .max_batch(64)
        .max_wait(Duration::from_millis(500))
        .build()
        .expect("engine");
    let engine = handle.engine.clone();
    let mut joins = Vec::new();
    for c in 0..6u64 {
        let engine = engine.clone();
        joins.push(std::thread::spawn(move || {
            infer_fire(&engine, Tensor::randn(&[1, 56, 56, 96], c)).map(|r| r.id)
        }));
    }
    // wait for an OBSERVABLE signal that the batcher has accepted at least
    // one request into the open batching window (a pre-send counter plus a
    // sleep would race on a loaded machine), then pull the plug mid-batch
    let t0 = std::time::Instant::now();
    let accepted_before_stop = loop {
        let accepted = engine.accepted("fire").expect("registered");
        if accepted >= 1 {
            break accepted;
        }
        assert!(t0.elapsed() < Duration::from_secs(10), "batcher never accepted a request");
        std::thread::yield_now();
    };
    handle.shutdown();
    let mut ok: u64 = 0;
    let mut clean_errors = 0;
    for j in joins {
        match j.join().expect("client thread must not panic") {
            Ok(_) => ok += 1,
            Err(e) => {
                let msg = e.to_string();
                assert!(
                    msg.contains("shut") || msg.contains("dropped"),
                    "unexpected error: {msg}"
                );
                clean_errors += 1;
            }
        }
    }
    assert_eq!(ok + clean_errors, 6, "every request must resolve");
    // every deadline-free request the batcher accepted before the stop
    // marker is guaranteed a successful response (dispatched, served,
    // never dropped)
    assert!(
        ok >= accepted_before_stop,
        "{accepted_before_stop} requests were accepted pre-shutdown but only {ok} served"
    );
}

#[test]
fn infer_after_shutdown_errors_cleanly() {
    let handle = fire_engine(2);
    let engine = handle.engine.clone();
    handle.shutdown();
    let err = infer_fire(&engine, Tensor::randn(&[1, 56, 56, 96], 1))
        .expect_err("post-shutdown infer must fail");
    let msg = err.to_string();
    assert!(msg.contains("shut") || msg.contains("dropped"), "{msg}");
}

#[test]
fn zero_window_serves_immediately() {
    // max_wait == 0 degenerates to batches of 1 — no hang, no panic
    let handle = fire_builder(1).max_wait(Duration::ZERO).build().expect("engine");
    let engine = handle.engine.clone();
    let r = infer_fire(&engine, Tensor::randn(&[1, 56, 56, 96], 5)).expect("infer");
    assert_eq!(r.batch_size, 1);
    assert_eq!(r.batch_index, 0);
    drop(engine);
    handle.shutdown();
}

#[test]
fn unknown_artifact_rejected_at_startup() {
    // holds with or without built artifacts (the simulated manifest knows
    // the same artifact names as aot.py)
    let err = EngineBuilder::new()
        .model(ModelSpec::new("x", "no_such_artifact", "squeezenet"))
        .build()
        .expect_err("unknown artifact must fail");
    assert!(err.to_string().contains("no_such_artifact"), "{err}");
}

#[test]
fn pool_batcher_coalesces_under_load() {
    // long batching window + parallel submitters -> mean batch > 1, even
    // with several workers behind the batcher
    let handle = fire_builder(2)
        .max_batch(8)
        .max_wait(Duration::from_millis(50))
        .build()
        .expect("engine");
    let engine = handle.engine.clone();
    let mut joins = Vec::new();
    for c in 0..8u64 {
        let engine = engine.clone();
        joins.push(std::thread::spawn(move || {
            infer_fire(&engine, Tensor::randn(&[1, 56, 56, 96], c)).expect("infer");
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let metrics = engine.metrics("fire").expect("registered");
    let m = metrics.lock().unwrap();
    assert_eq!(m.served, 8);
    assert!(
        m.mean_batch() > 1.0,
        "batcher never coalesced: {} batches for 8 requests",
        m.batches
    );
    assert!(m.percentile(0.5) > 0);
    drop(m);
    drop(engine);
    handle.shutdown();
}

#[test]
fn tcp_round_trip_over_worker_pool() {
    // the wire result must match a direct engine call bit-for-bit, with a
    // multi-worker pool behind the server
    let handle = fire_engine(2);
    let engine = handle.engine.clone();
    let server = Server::start("127.0.0.1:0", engine.clone()).expect("server");
    let addr = server.addr;

    let mut client = Client::connect(&addr).expect("connect");
    let x = Tensor::randn(&engine.input_shape("fire").expect("registered"), 5);
    let resp = client.infer(&x).expect("infer over tcp");
    assert_eq!(resp.output.shape, vec![1, 56, 56, 128]);
    assert_eq!(resp.model, "fire");
    assert!(resp.output.data.iter().all(|v| v.is_finite()));

    let direct = infer_fire(&engine, x).expect("direct infer");
    assert_eq!(resp.output.max_abs_diff(&direct.output), 0.0);

    server.stop();
    handle.shutdown();
}

// ===========================================================================
// artifact suite (requires `make artifacts`; skipped otherwise)

#[test]
fn engine_serves_one_request_on_real_artifacts() {
    if !artifacts_built() {
        eprintln!("artifacts not built; skipping");
        return;
    }
    let handle = fire_engine(1);
    let engine = handle.engine.clone();
    let x = Tensor::randn(&engine.input_shape("fire").expect("registered"), 1);
    let resp = infer_fire(&engine, x).expect("infer");
    assert_eq!(resp.output.shape, vec![1, 56, 56, 128]);
    assert!(resp.output.data.iter().all(|v| v.is_finite()));
    assert!(resp.simulated.seconds > 0.0 && resp.simulated.joules > 0.0);
    drop(engine);
    handle.shutdown();
}

#[test]
fn engine_results_deterministic_per_input_on_real_artifacts() {
    if !artifacts_built() {
        eprintln!("artifacts not built; skipping");
        return;
    }
    let handle = fire_engine(1);
    let engine = handle.engine.clone();
    let x = Tensor::randn(&engine.input_shape("fire").expect("registered"), 77);
    let a = infer_fire(&engine, x.clone()).unwrap();
    let b = infer_fire(&engine, x).unwrap();
    assert_eq!(a.output.max_abs_diff(&b.output), 0.0);
    drop(engine);
    handle.shutdown();
}

#[test]
fn tcp_server_multiple_clients_share_batcher() {
    if !artifacts_built() {
        eprintln!("artifacts not built; skipping");
        return;
    }
    let handle = fire_engine(1);
    let engine = handle.engine.clone();
    let server = Server::start("127.0.0.1:0", engine.clone()).expect("server");
    let addr = server.addr;
    let shape = engine.input_shape("fire").expect("registered");

    let mut joins = Vec::new();
    for c in 0..3u64 {
        let shape = shape.clone();
        joins.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).expect("connect");
            for i in 0..2 {
                let x = Tensor::randn(&shape, c * 10 + i);
                let r = client.infer(&x).expect("infer");
                assert_eq!(r.output.shape, vec![1, 56, 56, 128]);
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let metrics = engine.metrics("fire").expect("registered");
    assert_eq!(metrics.lock().unwrap().served, 6);
    assert!(server.connections.load(std::sync::atomic::Ordering::Relaxed) >= 3);
    server.stop();
    handle.shutdown();
}

#[test]
fn admission_control_sheds_overload() {
    if !artifacts_built() {
        eprintln!("artifacts not built; skipping");
        return;
    }
    use hetero_dnn::coordinator::admission::AdmissionConfig;
    // cap in-flight at 1 with a microscopic deadline: concurrent clients
    // must observe sheds while the single admitted request proceeds
    let handle = fire_builder(1)
        .admission(AdmissionConfig {
            deadline: Duration::from_millis(1),
            max_in_flight: 1,
            alpha: 0.5,
        })
        .build()
        .expect("engine");
    let engine = handle.engine.clone();
    let shape = engine.input_shape("fire").expect("registered");
    let mut joins = Vec::new();
    for c in 0..6u64 {
        let engine = engine.clone();
        let shape = shape.clone();
        joins.push(std::thread::spawn(move || {
            infer_fire(&engine, Tensor::randn(&shape, c)).is_ok()
        }));
    }
    let results: Vec<bool> = joins.into_iter().map(|j| j.join().unwrap()).collect();
    let ok = results.iter().filter(|&&b| b).count();
    assert!(ok >= 1, "at least one request must be served");
    assert!(ok < 6, "overload must shed something: {ok}/6 accepted");
    let ctl = engine.admission().expect("admission configured");
    assert!(ctl.rejected.load(std::sync::atomic::Ordering::Relaxed) > 0);
    drop(engine);
    handle.shutdown();
}
