//! Integration tests: the serving coordinator end to end (PJRT executor
//! thread, dynamic batcher, metrics). Requires `make artifacts`.

use hetero_dnn::config::Manifest;
use hetero_dnn::coordinator::server::{Client, Server};
use hetero_dnn::coordinator::{Coordinator, CoordinatorConfig};
use hetero_dnn::partition::Strategy;
use hetero_dnn::runtime::Tensor;
use std::time::Duration;

fn artifacts_built() -> bool {
    Manifest::load().is_ok()
}

/// Serve the small fire module artifact — fast enough for CI.
fn fire_cfg() -> CoordinatorConfig {
    CoordinatorConfig {
        artifact: "fire_full".into(),
        model: "squeezenet".into(),
        strategy: Strategy::Auto,
        max_batch: 4,
        max_wait: Duration::from_millis(1),
        seed: 0,
        admission: None,
    }
}

#[test]
fn coordinator_serves_one_request() {
    if !artifacts_built() {
        eprintln!("artifacts not built; skipping");
        return;
    }
    let handle = Coordinator::start(fire_cfg()).expect("start");
    let coord = handle.coordinator.clone();
    let x = Tensor::randn(coord.input_shape(), 1);
    let resp = coord.infer(x).expect("infer");
    assert_eq!(resp.output.shape, vec![1, 56, 56, 128]);
    assert!(resp.output.data.iter().all(|v| v.is_finite()));
    assert!(resp.simulated.seconds > 0.0 && resp.simulated.joules > 0.0);
    drop(coord);
    handle.shutdown();
}

#[test]
fn coordinator_serves_concurrent_clients() {
    if !artifacts_built() {
        eprintln!("artifacts not built; skipping");
        return;
    }
    let handle = Coordinator::start(fire_cfg()).expect("start");
    let coord = handle.coordinator.clone();
    let shape = coord.input_shape().to_vec();
    let mut joins = Vec::new();
    for c in 0..4u64 {
        let coord = coord.clone();
        let shape = shape.clone();
        joins.push(std::thread::spawn(move || {
            for i in 0..3u64 {
                let x = Tensor::randn(&shape, c * 100 + i);
                let r = coord.infer(x).expect("infer");
                assert_eq!(r.output.shape, vec![1, 56, 56, 128]);
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let m = coord.metrics.lock().unwrap();
    assert_eq!(m.served, 12);
    assert!(m.batches >= 1 && m.batches <= 12);
    assert!(m.percentile(0.5) > 0);
    drop(m);
    drop(coord);
    handle.shutdown();
}

#[test]
fn coordinator_results_deterministic_per_input() {
    if !artifacts_built() {
        eprintln!("artifacts not built; skipping");
        return;
    }
    let handle = Coordinator::start(fire_cfg()).expect("start");
    let coord = handle.coordinator.clone();
    let x = Tensor::randn(coord.input_shape(), 77);
    let a = coord.infer(x.clone()).unwrap();
    let b = coord.infer(x).unwrap();
    assert_eq!(a.output.max_abs_diff(&b.output), 0.0);
    drop(coord);
    handle.shutdown();
}

#[test]
fn coordinator_rejects_unknown_artifact() {
    if !artifacts_built() {
        eprintln!("artifacts not built; skipping");
        return;
    }
    let cfg = CoordinatorConfig { artifact: "no_such_artifact".into(), ..fire_cfg() };
    assert!(Coordinator::start(cfg).is_err());
}

#[test]
fn coordinator_rejects_unknown_model() {
    if !artifacts_built() {
        eprintln!("artifacts not built; skipping");
        return;
    }
    let cfg = CoordinatorConfig { model: "no_such_model".into(), ..fire_cfg() };
    assert!(Coordinator::start(cfg).is_err());
}

#[test]
fn tcp_server_round_trip() {
    if !artifacts_built() {
        eprintln!("artifacts not built; skipping");
        return;
    }
    let handle = Coordinator::start(fire_cfg()).expect("start");
    let server = Server::start("127.0.0.1:0", handle.coordinator.clone()).expect("server");
    let addr = server.addr;

    let mut client = Client::connect(&addr).expect("connect");
    let x = Tensor::randn(handle.coordinator.input_shape(), 5);
    let resp = client.infer(&x).expect("infer over tcp");
    assert_eq!(resp.output.shape, vec![1, 56, 56, 128]);
    assert!(resp.output.data.iter().all(|v| v.is_finite()));

    // the wire result must match a direct coordinator call bit-for-bit
    let direct = handle.coordinator.infer(x).expect("direct infer");
    assert_eq!(resp.output.max_abs_diff(&direct.output), 0.0);

    server.stop();
    handle.shutdown();
}

#[test]
fn tcp_server_multiple_clients_share_batcher() {
    if !artifacts_built() {
        eprintln!("artifacts not built; skipping");
        return;
    }
    let handle = Coordinator::start(fire_cfg()).expect("start");
    let server = Server::start("127.0.0.1:0", handle.coordinator.clone()).expect("server");
    let addr = server.addr;
    let shape = handle.coordinator.input_shape().to_vec();

    let mut joins = Vec::new();
    for c in 0..3u64 {
        let shape = shape.clone();
        joins.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).expect("connect");
            for i in 0..2 {
                let x = Tensor::randn(&shape, c * 10 + i);
                let r = client.infer(&x).expect("infer");
                assert_eq!(r.output.shape, vec![1, 56, 56, 128]);
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    assert_eq!(handle.coordinator.metrics.lock().unwrap().served, 6);
    assert!(server.connections.load(std::sync::atomic::Ordering::Relaxed) >= 3);
    server.stop();
    handle.shutdown();
}

#[test]
fn tcp_server_rejects_bad_shape() {
    if !artifacts_built() {
        eprintln!("artifacts not built; skipping");
        return;
    }
    let handle = Coordinator::start(fire_cfg()).expect("start");
    let server = Server::start("127.0.0.1:0", handle.coordinator.clone()).expect("server");
    let mut client = Client::connect(&server.addr).expect("connect");
    let bad = Tensor::zeros(&[1, 8, 8, 3]);
    let err = client.infer(&bad).expect_err("bad shape must error");
    assert!(err.to_string().contains("shape"), "{err}");
    server.stop();
    handle.shutdown();
}

#[test]
fn batcher_coalesces_under_load() {
    if !artifacts_built() {
        eprintln!("artifacts not built; skipping");
        return;
    }
    // long batching window + parallel submitters -> mean batch > 1
    let cfg = CoordinatorConfig {
        max_batch: 8,
        max_wait: Duration::from_millis(50),
        ..fire_cfg()
    };
    let handle = Coordinator::start(cfg).expect("start");
    let coord = handle.coordinator.clone();
    let shape = coord.input_shape().to_vec();
    let mut joins = Vec::new();
    for c in 0..8u64 {
        let coord = coord.clone();
        let shape = shape.clone();
        joins.push(std::thread::spawn(move || {
            let x = Tensor::randn(&shape, c);
            coord.infer(x).expect("infer");
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let m = coord.metrics.lock().unwrap();
    assert_eq!(m.served, 8);
    assert!(
        m.mean_batch() > 1.0,
        "batcher never coalesced: {} batches for 8 requests",
        m.batches
    );
    drop(m);
    drop(coord);
    handle.shutdown();
}

#[test]
fn admission_control_sheds_overload() {
    if !artifacts_built() {
        eprintln!("artifacts not built; skipping");
        return;
    }
    use hetero_dnn::coordinator::admission::AdmissionConfig;
    // cap in-flight at 1 with a microscopic deadline: concurrent clients
    // must observe sheds while the single admitted request proceeds
    let cfg = CoordinatorConfig {
        admission: Some(AdmissionConfig {
            deadline: Duration::from_millis(1),
            max_in_flight: 1,
            alpha: 0.5,
        }),
        ..fire_cfg()
    };
    let handle = Coordinator::start(cfg).expect("start");
    let coord = handle.coordinator.clone();
    let shape = coord.input_shape().to_vec();
    let mut joins = Vec::new();
    for c in 0..6u64 {
        let coord = coord.clone();
        let shape = shape.clone();
        joins.push(std::thread::spawn(move || coord.infer(Tensor::randn(&shape, c)).is_ok()));
    }
    let results: Vec<bool> = joins.into_iter().map(|j| j.join().unwrap()).collect();
    let ok = results.iter().filter(|&&b| b).count();
    assert!(ok >= 1, "at least one request must be served");
    assert!(ok < 6, "overload must shed something: {ok}/6 accepted");
    let ctl = coord.admission.as_ref().unwrap();
    assert!(ctl.rejected.load(std::sync::atomic::Ordering::Relaxed) > 0);
    drop(coord);
    handle.shutdown();
}

#[test]
fn admission_disabled_accepts_everything() {
    if !artifacts_built() {
        eprintln!("artifacts not built; skipping");
        return;
    }
    let handle = Coordinator::start(fire_cfg()).expect("start");
    let coord = handle.coordinator.clone();
    assert!(coord.admission.is_none());
    drop(coord);
    handle.shutdown();
}
