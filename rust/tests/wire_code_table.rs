//! Spec-drift gate: PROTOCOL.md §6's wire-code table must list exactly
//! the codes the implementation can emit — `RuntimeError::CODES` (one
//! per error variant, tied to `RuntimeError::code()` by the runtime unit
//! tests) plus the protocol-layer codes. Run in CI's docs job; adding an
//! error variant or a table row without the other fails the build.

use hetero_dnn::coordinator::protocol::PROTOCOL_CODES;
use hetero_dnn::runtime::RuntimeError;
use std::collections::BTreeSet;
use std::path::Path;

#[test]
fn wire_code_table_matches_source_of_truth() {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("../PROTOCOL.md");
    let md = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("PROTOCOL.md at the repo root ({}): {e}", path.display()));
    let section = md
        .split("## 6.")
        .nth(1)
        .expect("PROTOCOL.md has a '## 6.' wire-code-table section");
    let section = section.split("\n## ").next().expect("section body");

    // table rows look like: | `code` | meaning … | connection |
    let mut table = BTreeSet::new();
    for line in section.lines() {
        if let Some(rest) = line.trim().strip_prefix("| `") {
            let code = rest.split('`').next().expect("closing backtick");
            assert!(
                table.insert(code.to_string()),
                "code {code:?} listed twice in PROTOCOL.md §6"
            );
        }
    }

    let expected: BTreeSet<String> = RuntimeError::CODES
        .iter()
        .chain(PROTOCOL_CODES)
        .map(|s| s.to_string())
        .collect();
    assert_eq!(
        table, expected,
        "PROTOCOL.md §6 drifted from RuntimeError::CODES + protocol::PROTOCOL_CODES — \
         update the table and the source together"
    );
}
