//! Protocol conformance: the shipped codecs vs PROTOCOL.md Appendix A.
//!
//! PROTOCOL.md is the normative spec; these tests encode the appendix's
//! worked exchange with the real codecs and require **byte equality**
//! with the published hex dumps, so the spec and the implementation
//! cannot drift apart silently. The golden bytes are parsed out of
//! PROTOCOL.md itself (markers `<!-- golden:NAME -->`), not duplicated
//! here.

use hetero_dnn::coordinator::protocol::{self, RequestHeader, ResponseHeader};
use std::path::Path;

fn protocol_md() -> String {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("../PROTOCOL.md");
    std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("PROTOCOL.md at the repo root ({}): {e}", path.display()))
}

/// Extract the hex dump fenced right after `<!-- golden:NAME -->`.
fn golden(name: &str) -> Vec<u8> {
    let md = protocol_md();
    let marker = format!("<!-- golden:{name} -->");
    let at = md.find(&marker).unwrap_or_else(|| panic!("marker {marker} missing in PROTOCOL.md"));
    let rest = &md[at + marker.len()..];
    let fence = rest.find("```").expect("opening fence after the marker");
    let after_fence = &rest[fence..];
    let body_start = after_fence.find('\n').expect("fence line ends") + 1;
    let body = &after_fence[body_start..];
    let end = body.find("```").expect("closing fence");
    let mut bytes = Vec::new();
    for line in body[..end].lines() {
        let Some((_, hex)) = line.split_once(':') else { continue };
        for tok in hex.split_whitespace() {
            bytes.push(
                u8::from_str_radix(tok, 16)
                    .unwrap_or_else(|_| panic!("bad hex byte {tok:?} in golden:{name}")),
            );
        }
    }
    assert!(!bytes.is_empty(), "golden:{name} dump is empty");
    bytes
}

/// The appendix's request: id 7, model 0, priority high, deadline
/// 2000 µs, shape [1, 3], payload [0.5, -1.5, 2.0].
fn appendix_request() -> (RequestHeader, Vec<f32>) {
    (
        RequestHeader { id: 7, model: 0, priority: 1, deadline_us: 2_000, dims: vec![1, 3] },
        vec![0.5, -1.5, 2.0],
    )
}

#[test]
fn hello_frame_matches_appendix() {
    assert_eq!(protocol::encode_hello(), golden("hello"));
}

#[test]
fn hello_ack_frame_matches_appendix() {
    let table = vec![("fire".to_string(), vec![1, 56, 56, 96])];
    assert_eq!(protocol::encode_hello_ack(protocol::VERSION, &table), golden("hello_ack"));
}

#[test]
fn request_frame_matches_appendix() {
    let (header, payload) = appendix_request();
    assert_eq!(protocol::encode_request(&header, &payload), golden("request"));
}

#[test]
fn request_frame_decodes_back_to_appendix_fields() {
    let bytes = golden("request");
    let (decoded, payload_at) = protocol::decode_request_header(&bytes).expect("golden decodes");
    let (expected, payload) = appendix_request();
    assert_eq!(decoded, expected);
    assert_eq!(&bytes[payload_at..], &protocol::f32_bytes(&payload)[..]);
}

#[test]
fn response_head_frame_matches_appendix() {
    let head = ResponseHeader {
        id: 7,
        model: 0,
        batch_size: 4,
        exec_us: 250,
        queued_us: 90,
        chunk_elems: 3,
        sim_ms: 1.25,
        sim_mj: 2.5,
        cached: false,
        last: true,
        dims: vec![1, 3],
    };
    let mut frame = protocol::encode_response_head(&head);
    frame.extend_from_slice(&protocol::f32_bytes(&[0.25, 0.5, 0.75]));
    assert_eq!(frame, golden("response"));
}

#[test]
fn response_head_decodes_back_to_appendix_fields() {
    let bytes = golden("response");
    let mut pre = [0u8; 8];
    pre.copy_from_slice(&bytes[..8]);
    let p = protocol::parse_prelude(&pre).expect("golden prelude parses");
    assert_eq!(p.kind, protocol::KIND_RESPONSE);
    let h = protocol::decode_response_body(&p, &bytes[8..]).expect("golden body decodes");
    assert_eq!((h.id, h.model, h.batch_size), (7, 0, 4));
    assert_eq!((h.exec_us, h.queued_us, h.chunk_elems), (250, 90, 3));
    assert_eq!((h.sim_ms, h.sim_mj), (1.25, 2.5));
    assert!(h.last && !h.cached);
    assert_eq!(h.dims, vec![1, 3]);
}

#[test]
fn chunk_frame_matches_appendix() {
    let mut frame = protocol::encode_chunk_header(7, 1, 2, true);
    frame.extend_from_slice(&protocol::f32_bytes(&[1.0, -1.0]));
    assert_eq!(frame, golden("chunk"));
}

#[test]
fn error_frame_matches_appendix() {
    assert_eq!(
        protocol::encode_error(9, "unknown_model", "model #3 not registered", false),
        golden("error")
    );
}

#[test]
fn health_frame_matches_appendix() {
    assert_eq!(protocol::encode_health(3), golden("health"));
}

#[test]
fn health_ack_frame_matches_appendix() {
    let h = hetero_dnn::coordinator::NodeHealth {
        in_flight: 2,
        queue_depth: 5,
        cache_hit_rate: 0.75,
    };
    assert_eq!(protocol::encode_health_ack(3, &h), golden("health_ack"));
}

#[test]
fn health_ack_decodes_back_to_appendix_fields() {
    let bytes = golden("health_ack");
    let (id, h) = protocol::decode_health_ack(&bytes[8..]).expect("golden decodes");
    assert_eq!(id, 3);
    assert_eq!((h.in_flight, h.queue_depth, h.cache_hit_rate), (2, 5, 0.75));
}

/// The appendix's stats breakdown: stage `i` (0-based) reports
/// `count = (i+1)×100` and mean/p50/p99 = count+1/+2/+3.
fn appendix_stats() -> hetero_dnn::obs::NodeStats {
    let mut s = hetero_dnn::obs::NodeStats::default();
    for (i, st) in s.stages.iter_mut().enumerate() {
        let base = (i as u64 + 1) * 100;
        *st = hetero_dnn::obs::StageStats {
            count: base,
            mean_us: base + 1,
            p50_us: base + 2,
            p99_us: base + 3,
        };
    }
    s
}

#[test]
fn stats_frame_matches_appendix() {
    assert_eq!(protocol::encode_stats(4), golden("stats"));
}

#[test]
fn stats_ack_frame_matches_appendix() {
    assert_eq!(protocol::encode_stats_ack(4, &appendix_stats()), golden("stats_ack"));
}

#[test]
fn stats_ack_decodes_back_to_appendix_fields() {
    let bytes = golden("stats_ack");
    let (id, s) = protocol::decode_stats_ack(&bytes[8..]).expect("golden decodes");
    assert_eq!(id, 4);
    assert_eq!(s, appendix_stats());
}
