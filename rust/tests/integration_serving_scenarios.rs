//! Integration tests: the ISSUE 3 serving scenarios on the live Engine —
//! content-digest result cache (bit-identity, LRU eviction), per-model
//! admission budgets (isolation under load), and model hot-swap
//! (register/retire with zero disturbance to sibling traffic).
//!
//! Everything runs against the deterministic runtime (simulated fallback
//! when artifacts are not built), so bit-identity assertions are exact.

use hetero_dnn::coordinator::{EngineBuilder, InferenceRequest, ModelSpec};
use hetero_dnn::runtime::{Runtime, RuntimeError, Tensor};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// What the engine must return for `x` on `artifact` with seed-0 weights:
/// a direct, per-request execution on a private runtime.
fn reference_output(artifact: &str, x: &Tensor) -> Tensor {
    let rt = Runtime::new_or_simulated();
    let exe = rt.load(artifact).expect("load");
    let mut inputs = rt.synth_inputs(artifact, 0).expect("synth");
    inputs[0] = x.clone();
    exe.run(&inputs).expect("run").remove(0)
}

// ===========================================================================
// result cache

#[test]
fn cache_hit_is_bit_identical_to_uncached_execution() {
    let handle = EngineBuilder::new()
        .max_wait(Duration::ZERO)
        .model(ModelSpec::new("fire", "fire_full", "squeezenet").cache(8))
        .build()
        .expect("engine");
    let engine = handle.engine.clone();
    let x = Tensor::randn(&[1, 56, 56, 96], 11);

    let miss = engine.infer(InferenceRequest::new("fire", x.clone())).expect("miss infer");
    assert!(!miss.cached, "first sight of an input must execute");
    let hit = engine.infer(InferenceRequest::new("fire", x.clone())).expect("hit infer");
    assert!(hit.cached, "second sight of an input must hit the cache");
    assert_eq!(hit.exec, Duration::ZERO, "a hit executes nothing");

    // bit-identical across miss → hit, and vs a direct uncached run
    assert_eq!(hit.output.max_abs_diff(&miss.output), 0.0, "hit must equal miss");
    let want = reference_output("fire_full", &x);
    assert_eq!(hit.output.max_abs_diff(&want), 0.0, "hit must equal direct execution");

    let metrics = engine.metrics("fire").expect("registered");
    {
        let m = metrics.lock().unwrap();
        assert_eq!(m.cache_hits, 1);
        assert_eq!(m.cache_misses, 1);
        assert_eq!(m.served, 1, "only the miss executed");
        assert!((m.cache_hit_rate() - 0.5).abs() < 1e-12);
    }
    drop(engine);
    handle.shutdown();
}

#[test]
fn cache_evicts_lru_under_capacity() {
    // capacity 2: after serving inputs a, b, c the cache holds {b, c};
    // re-sending a must miss (and re-insert it, evicting b)
    let handle = EngineBuilder::new()
        .max_wait(Duration::ZERO)
        .model(ModelSpec::new("fire", "fire_full", "squeezenet").cache(2))
        .build()
        .expect("engine");
    let engine = handle.engine.clone();
    let inputs: Vec<Tensor> = (0..3).map(|s| Tensor::randn(&[1, 56, 56, 96], 100 + s)).collect();
    for x in &inputs {
        let r = engine.infer(InferenceRequest::new("fire", x.clone())).expect("infer");
        assert!(!r.cached, "three distinct inputs: all misses");
    }
    let metrics = engine.metrics("fire").expect("registered");
    assert_eq!(metrics.lock().unwrap().cache_evictions, 1, "third insert evicts the oldest");

    // newest two are resident, the oldest was evicted
    let c = engine.infer(InferenceRequest::new("fire", inputs[2].clone())).expect("infer c");
    assert!(c.cached, "newest entry must be resident");
    let b = engine.infer(InferenceRequest::new("fire", inputs[1].clone())).expect("infer b");
    assert!(b.cached, "second-newest entry must be resident");
    let a = engine.infer(InferenceRequest::new("fire", inputs[0].clone())).expect("infer a");
    assert!(!a.cached, "evicted entry must re-execute");
    assert_eq!(
        a.output.max_abs_diff(&reference_output("fire_full", &inputs[0])),
        0.0,
        "re-executed result must still be exact"
    );
    drop(engine);
    handle.shutdown();
}

// ===========================================================================
// per-model admission budgets

#[test]
fn budget_rejects_hot_model_without_starving_siblings() {
    // a long batching window parks the first fire request inside the
    // batcher, pinning fire's in-flight count at its budget of 1
    let handle = EngineBuilder::new()
        .max_batch(8)
        .max_wait(Duration::from_millis(400))
        .model(ModelSpec::new("fire", "fire_full", "squeezenet").budget(1))
        .model(ModelSpec::new("bottleneck", "bottleneck_full", "mobilenetv2_05"))
        .build()
        .expect("engine");
    let engine = handle.engine.clone();

    let parked = {
        let engine = engine.clone();
        std::thread::spawn(move || {
            engine.infer(InferenceRequest::new("fire", Tensor::randn(&[1, 56, 56, 96], 1)))
        })
    };
    let t0 = std::time::Instant::now();
    while engine.in_flight("fire") != Some(1) {
        assert!(t0.elapsed() < Duration::from_secs(10), "first request never went in flight");
        std::thread::yield_now();
    }

    // fire is at budget: the second request must be rejected, not queued
    let err = engine
        .infer(InferenceRequest::new("fire", Tensor::randn(&[1, 56, 56, 96], 2)))
        .expect_err("over-budget request must be rejected");
    match &err {
        RuntimeError::BudgetExhausted { model, in_flight, budget } => {
            assert_eq!(model, "fire");
            assert_eq!(*in_flight, 1);
            assert_eq!(*budget, 1);
        }
        other => panic!("expected BudgetExhausted, got {other}"),
    }
    assert_eq!(err.code(), "budget_exhausted");

    // the sibling model is NOT starved while fire sits at its cap
    let sibling = engine
        .infer(InferenceRequest::new("bottleneck", Tensor::randn(&[1, 28, 28, 16], 3)))
        .expect("sibling must still serve");
    assert_eq!(sibling.output.shape, vec![1, 28, 28, 16]);

    // the parked request completes and releases its budget slot
    let first = parked.join().unwrap().expect("parked request must serve");
    assert_eq!(first.output.shape, vec![1, 56, 56, 128]);
    assert_eq!(engine.in_flight("fire"), Some(0));
    let ok = engine
        .infer(InferenceRequest::new("fire", Tensor::randn(&[1, 56, 56, 96], 4)))
        .expect("slot released: fire serves again");
    assert!(!ok.output.data.is_empty());

    let metrics = engine.metrics("fire").expect("registered");
    assert_eq!(metrics.lock().unwrap().budget_rejected, 1);
    drop(engine);
    handle.shutdown();
}

#[test]
fn budget_rejection_returns_the_shared_admission_slot() {
    use hetero_dnn::coordinator::admission::AdmissionConfig;
    // shared cap 2, fire budget 1: park one fire request (slot 1 of 2),
    // then an over-budget fire request briefly takes slot 2 and must give
    // it back on rejection — otherwise the sibling would be shed at the cap
    let handle = EngineBuilder::new()
        .max_batch(8)
        .max_wait(Duration::from_millis(400))
        .admission(AdmissionConfig {
            deadline: Duration::from_secs(5),
            max_in_flight: 2,
            alpha: 0.2,
        })
        .model(ModelSpec::new("fire", "fire_full", "squeezenet").budget(1))
        .model(ModelSpec::new("bottleneck", "bottleneck_full", "mobilenetv2_05"))
        .build()
        .expect("engine");
    let engine = handle.engine.clone();

    let parked = {
        let engine = engine.clone();
        std::thread::spawn(move || {
            engine.infer(InferenceRequest::new("fire", Tensor::randn(&[1, 56, 56, 96], 1)))
        })
    };
    let t0 = std::time::Instant::now();
    while engine.in_flight("fire") != Some(1) {
        assert!(t0.elapsed() < Duration::from_secs(10), "first request never went in flight");
        std::thread::yield_now();
    }

    let err = engine
        .infer(InferenceRequest::new("fire", Tensor::randn(&[1, 56, 56, 96], 2)))
        .expect_err("fire is at its budget");
    assert_eq!(err.code(), "budget_exhausted");

    // only the parked request may hold a shared slot now; without the
    // cancel the controller would sit at its cap of 2 and shed the sibling
    let ctl = engine.admission().expect("admission configured");
    assert_eq!(ctl.in_flight(), 1, "budget rejection must return the shared slot");
    engine
        .infer(InferenceRequest::new("bottleneck", Tensor::randn(&[1, 28, 28, 16], 3)))
        .expect("sibling must be admitted after the cancel");

    parked.join().unwrap().expect("parked request must serve");
    drop(engine);
    handle.shutdown();
}

// ===========================================================================
// hot-swap (acceptance: register + retire on a live engine with ZERO
// failed in-flight requests on other models)

#[test]
fn hot_swap_register_and_retire_on_live_engine_without_sibling_failures() {
    let handle = EngineBuilder::new()
        .max_batch(4)
        .max_wait(Duration::from_micros(200))
        .model(ModelSpec::new("fire", "fire_full", "squeezenet").workers(2))
        .build()
        .expect("engine");
    let engine = handle.engine.clone();

    // sustained sibling traffic across the whole register/retire cycle
    let stop = Arc::new(AtomicBool::new(false));
    let traffic = {
        let engine = engine.clone();
        let stop = stop.clone();
        std::thread::spawn(move || -> (u64, Vec<String>) {
            let mut ok = 0u64;
            let mut failures = Vec::new();
            let mut i = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let x = Tensor::randn(&[1, 56, 56, 96], i);
                match engine.infer(InferenceRequest::new("fire", x)) {
                    Ok(r) => {
                        assert_eq!(r.output.shape, vec![1, 56, 56, 128]);
                        ok += 1;
                    }
                    Err(e) => failures.push(e.to_string()),
                }
                i += 1;
            }
            (ok, failures)
        })
    };

    // register a second model on the LIVE engine and serve it
    engine
        .register(ModelSpec::new("bottleneck", "bottleneck_full", "mobilenetv2_05").workers(2))
        .expect("live register");
    assert_eq!(engine.models(), vec!["fire", "bottleneck"], "registration order");
    let x = Tensor::randn(&[1, 28, 28, 16], 7);
    let resp = engine
        .infer(InferenceRequest::new("bottleneck", x.clone()))
        .expect("hot-swapped model must serve");
    assert_eq!(
        resp.output.max_abs_diff(&reference_output("bottleneck_full", &x)),
        0.0,
        "hot-swapped model must serve exact results"
    );

    // retire it again — only its own pool drains
    engine.retire("bottleneck").expect("live retire");
    assert_eq!(engine.models(), vec!["fire"]);
    let err = engine
        .infer(InferenceRequest::new("bottleneck", x))
        .expect_err("retired model must be unknown");
    assert!(matches!(err, RuntimeError::UnknownModel { .. }), "{err}");
    assert!(
        matches!(engine.retire("bottleneck"), Err(RuntimeError::UnknownModel { .. })),
        "double retire must fail cleanly"
    );

    // let the sibling run a little longer post-retire, then count failures
    std::thread::sleep(Duration::from_millis(30));
    stop.store(true, Ordering::Relaxed);
    let (ok, failures) = traffic.join().expect("traffic thread");
    assert!(ok > 0, "sibling traffic must have flowed during the swap");
    assert!(
        failures.is_empty(),
        "zero failed in-flight requests on other models, got {failures:?}"
    );
    drop(engine);
    handle.shutdown();
}

#[test]
fn retiring_the_last_model_leaves_an_empty_but_working_engine() {
    let handle = EngineBuilder::new()
        .model(ModelSpec::new("fire", "fire_full", "squeezenet"))
        .build()
        .expect("engine");
    let engine = handle.engine.clone();
    engine.retire("fire").expect("retire last model");
    assert!(engine.models().is_empty());
    assert_eq!(engine.default_model(), None);
    let err = engine
        .infer(InferenceRequest::new("fire", Tensor::zeros(&[1, 56, 56, 96])))
        .expect_err("no models: everything is unknown");
    assert!(matches!(err, RuntimeError::UnknownModel { .. }), "{err}");

    // the registry refills on a live register
    engine
        .register(ModelSpec::new("fire", "fire_full", "squeezenet"))
        .expect("re-register after retire");
    let r = engine
        .infer(InferenceRequest::new("fire", Tensor::randn(&[1, 56, 56, 96], 1)))
        .expect("re-registered model serves");
    assert_eq!(r.output.shape, vec![1, 56, 56, 128]);
    drop(engine);
    handle.shutdown();
}

#[test]
fn hot_swapped_model_can_bring_its_own_cache_and_budget() {
    let handle = EngineBuilder::new()
        .max_wait(Duration::ZERO)
        .model(ModelSpec::new("fire", "fire_full", "squeezenet"))
        .build()
        .expect("engine");
    let engine = handle.engine.clone();
    engine
        .register(
            ModelSpec::new("bottleneck", "bottleneck_full", "mobilenetv2_05").cache(4).budget(8),
        )
        .expect("register with scenarios");
    let x = Tensor::randn(&[1, 28, 28, 16], 9);
    let miss = engine.infer(InferenceRequest::new("bottleneck", x.clone())).expect("miss");
    assert!(!miss.cached);
    let hit = engine.infer(InferenceRequest::new("bottleneck", x)).expect("hit");
    assert!(hit.cached, "a hot-swapped model's cache must work");
    assert_eq!(hit.output.max_abs_diff(&miss.output), 0.0);
    drop(engine);
    handle.shutdown();
}
