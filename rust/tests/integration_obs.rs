//! Flight-recorder battery: the observability acceptance criteria
//! (DESIGN.md §15).
//!
//! Four claims pin the recorder against the live hetero engine:
//!
//! 1. **Span chains** — a traced run on each paper net records exactly
//!    one `admitted` → `reply_written` chain per request, with nothing
//!    dropped or overwritten.
//! 2. **Stage tiling** — the per-stage breakdown histograms tile the
//!    end-to-end latency: their summed means reconcile with the e2e p50
//!    within 10%.
//! 3. **Exact hold accounting** — the traced device-hold totals equal
//!    the node arbiter's [`ArbiterCounters`] holds to the microsecond,
//!    per device — the same identity the contention battery pins for
//!    tenant lane counters.
//! 4. **Zero interference** — outputs stay bit-identical with tracing
//!    on, and the measured Chrome trace parses as valid JSON with at
//!    least one span on every pipeline lane.
//!
//! Plus the HEALTH-side hardening ISSUE 10 asks for: `node_health()`
//! sampled concurrently with hot-swap retire/register churn never
//! panics and never reports an underflowed (wrapped) gauge.
//!
//! [`ArbiterCounters`]: hetero_dnn::metrics::device::ArbiterCounters

use hetero_dnn::config::json::{self, Json};
use hetero_dnn::coordinator::{
    Completion, EngineBuilder, EngineHandle, InferenceRequest, ModelSpec,
};
use hetero_dnn::partition::{Resource, Strategy};
use hetero_dnn::runtime::Tensor;
use hetero_dnn::sched::trace::device_track;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Mutex};
use std::time::Duration;

const NETS: [&str; 3] = ["squeezenet", "mobilenetv2_05", "shufflenetv2_05"];

/// Same discipline as integration_contention.rs: lanes busy-spin
/// simulated device time, so traced runs serialize against each other
/// rather than descheduling each other's lanes on a small runner.
static SPIN: Mutex<()> = Mutex::new(());

fn spin_guard() -> std::sync::MutexGuard<'static, ()> {
    SPIN.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// A shared-node engine with the flight recorder on: the configuration
/// the acceptance criteria are stated against (hetero placement, all
/// three devices behind the arbiter, every request traced end to end).
fn traced_engine(nets: &[&str]) -> EngineHandle {
    let mut b = EngineBuilder::new().shared_devices().max_wait(Duration::ZERO).tracing();
    for net in nets {
        b = b.model(ModelSpec::net(net).placement(Strategy::Paper));
    }
    b.build().expect("traced shared-device engine")
}

#[test]
fn traced_hetero_run_reconciles_on_all_paper_nets() {
    let _spin = spin_guard();
    const N: usize = 8;
    for net in NETS {
        let handle = traced_engine(&[net]);
        let engine = handle.engine.clone();
        let shape = engine.input_shape(net).expect("registered");
        // drive sequentially: with one request in the house at a time,
        // every microsecond of the e2e span is tiled by exactly one
        // stage (no untimed overlap), so the reconciliation below is a
        // real identity check and not a scheduling accident
        for s in 0..N as u64 {
            let x = Tensor::randn(&shape, 7 + s);
            engine.infer(InferenceRequest::new(net, x)).expect("traced infer");
        }
        let snap = engine.trace_snapshot().expect("tracing is on");
        assert_eq!(snap.dropped, 0, "{net}: recorder refused an emit");
        assert_eq!(snap.overwritten, 0, "{net}: a ring wrapped");

        // (a) exactly one admitted -> reply_written chain per request
        let chains = snap.chains();
        assert_eq!(chains.len(), N, "{net}: one span chain per request");
        for (trace, &(admitted, replied)) in &chains {
            assert_eq!((admitted, replied), (1, 1), "{net} {trace}: chain endpoints");
        }

        // (b) the stage means tile the end-to-end latency
        let stage_sum: f64 = snap.breakdown.stages().iter().map(|h| h.mean()).sum();
        let p50 = snap.breakdown.e2e.quantile(0.5) as f64;
        assert!(p50 > 0.0, "{net}: empty e2e histogram");
        assert!(
            (stage_sum - p50).abs() <= 0.10 * p50,
            "{net}: summed stage means {stage_sum:.0}us vs e2e p50 {p50:.0}us (>10% apart)"
        );

        // (c) device-hold totals match the node counters exactly: both
        // sides truncate the same wall Duration per hold, so this is
        // equality, not tolerance
        let node = engine.node_device_metrics().expect("shared node metrics");
        let checks = [
            ("gpu", &node.gpu, Resource::Gpu),
            ("fpga", &node.fpga, Resource::Fpga),
            ("link", &node.link, Resource::Link),
        ];
        for (name, arb, dev) in checks {
            assert!(arb.grants() > 0, "{net}: {name} never granted");
            assert_eq!(
                snap.breakdown.hold_us(dev),
                arb.holds().as_micros() as u64,
                "{net}: traced {name} holds vs node arbiter counter"
            );
        }

        // (d) the measured Chrome trace is valid JSON with at least one
        // complete span on every pipeline lane track
        let text = snap.chrome_trace_json();
        let doc = json::parse(&text).expect("measured trace must parse as JSON");
        let events = doc.get("traceEvents").expect("traceEvents").as_arr().expect("array");
        for dev in [Resource::Gpu, Resource::Fpga, Resource::Link] {
            let (tid, lane) = device_track(dev);
            let spans = events
                .iter()
                .filter(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
                .filter(|e| e.get("tid").and_then(Json::as_usize) == Some(tid as usize))
                .count();
            assert!(spans >= 1, "{net}: no hold spans on lane {lane:?}");
        }

        // the wire-facing summary is fed from the same breakdown
        let stats = engine.node_stats();
        assert!(!stats.is_empty(), "{net}: node stats empty after a traced run");
        drop(engine);
        handle.shutdown();
    }
}

#[test]
fn tracing_does_not_change_output_bits() {
    let _spin = spin_guard();
    // the overhead contract's semantic half: turning the recorder on
    // must not change a single output bit on any paper net
    for net in NETS {
        let plain = EngineBuilder::new()
            .max_wait(Duration::ZERO)
            .model(ModelSpec::net(net).placement(Strategy::Paper))
            .build()
            .expect("plain engine");
        let traced = EngineBuilder::new()
            .max_wait(Duration::ZERO)
            .tracing()
            .model(ModelSpec::net(net).placement(Strategy::Paper))
            .build()
            .expect("traced engine");
        assert!(plain.engine.trace_snapshot().is_none(), "recorder is opt-in");
        assert!(traced.engine.trace_snapshot().is_some());

        let shape = plain.engine.input_shape(net).expect("registered");
        for s in 0..3u64 {
            let x = Tensor::randn(&shape, 40 + s);
            let a = plain.engine.infer(InferenceRequest::new(net, x.clone())).expect("plain");
            let b = traced.engine.infer(InferenceRequest::new(net, x)).expect("traced");
            assert_eq!(a.output, b.output, "{net}: tracing changed the bits");
        }
        plain.shutdown();
        traced.shutdown();
    }
}

#[test]
fn completions_carry_the_trace_id_exactly_when_tracing_is_on() {
    let _spin = spin_guard();
    let traced = traced_engine(&["squeezenet"]);
    let shape = traced.engine.input_shape("squeezenet").expect("registered");
    let (sink, done) = mpsc::channel::<Completion>();
    for tag in 0..3u64 {
        let x = Tensor::randn(&shape, tag);
        let req = InferenceRequest::new("squeezenet", x);
        traced.engine.submit(req, tag, &sink).expect("submit");
        let c = done.recv().expect("completion");
        c.result.expect("infer ok");
        let trace = c.trace.expect("traced engine must stamp completions");
        assert_eq!(trace.0, tag, "trace ids allocate in admission order");
    }
    traced.shutdown();

    // and never when it is off
    let plain = EngineBuilder::new()
        .max_wait(Duration::ZERO)
        .model(ModelSpec::net("squeezenet").placement(Strategy::Paper))
        .build()
        .expect("plain engine");
    let shape = plain.engine.input_shape("squeezenet").expect("registered");
    let x = Tensor::randn(&shape, 9);
    plain.engine.submit(InferenceRequest::new("squeezenet", x), 0, &sink).expect("submit");
    let c = done.recv().expect("completion");
    c.result.expect("infer ok");
    assert!(c.trace.is_none(), "untraced engine must not invent trace ids");
    plain.shutdown();
}

#[test]
fn node_health_stays_sane_under_concurrent_hot_swap() {
    let _spin = spin_guard();
    // HEALTH aggregation samples per-model gauges racily (by design);
    // what it must never do is panic or report a wrapped u64 while a
    // model is half-retired under live traffic
    let handle = EngineBuilder::new()
        .max_wait(Duration::ZERO)
        .model(ModelSpec::new("fire", "fire_full", "squeezenet").workers(2))
        .model(ModelSpec::new("swap", "fire_full", "squeezenet"))
        .build()
        .expect("engine");
    let engine = handle.engine.clone();
    let stop = AtomicBool::new(false);
    std::thread::scope(|s| {
        // live traffic: the stable model must always answer; the
        // swapping one may bounce off a retire window with a clean error
        s.spawn(|| {
            let shape = engine.input_shape("fire").expect("registered");
            for i in 0..40u64 {
                let x = Tensor::randn(&shape, i);
                engine.infer(InferenceRequest::new("fire", x)).expect("stable model");
                let y = Tensor::randn(&shape, 1_000 + i);
                match engine.infer(InferenceRequest::new("swap", y)) {
                    Ok(_) => {}
                    Err(e) => assert!(
                        matches!(e.code(), "unknown_model" | "model_retiring" | "serving"),
                        "unexpected error during swap churn: {e}"
                    ),
                }
            }
            stop.store(true, Ordering::SeqCst);
        });
        // the operator: retire + re-register the swapping model in a loop
        s.spawn(|| {
            while !stop.load(Ordering::SeqCst) {
                let _ = engine.retire("swap");
                let _ = engine.register(ModelSpec::new("swap", "fire_full", "squeezenet"));
                std::thread::yield_now();
            }
        });
        // the prober: every sample must be internally consistent
        s.spawn(|| {
            while !stop.load(Ordering::SeqCst) {
                let h = engine.node_health();
                assert!(h.in_flight < 1 << 32, "in_flight wrapped: {}", h.in_flight);
                assert!(
                    h.queue_depth <= h.in_flight,
                    "queued {} > in flight {}",
                    h.queue_depth,
                    h.in_flight
                );
                assert!(
                    (0.0..=1.0).contains(&h.cache_hit_rate),
                    "hit rate out of range: {}",
                    h.cache_hit_rate
                );
                std::thread::yield_now();
            }
        });
    });
    drop(engine);
    handle.shutdown();
}
