//! Cluster-tier integration: N in-process nodes behind the
//! digest-affinity router, exercised over the real TCP wire (v2
//! protocol). The claims under test are the ISSUE's acceptance bar:
//!
//! - routing is **transparent**: for every paper model graph, the
//!   routed answer is bit-identical to a direct single-node answer;
//! - affinity **pays**: with digest affinity on, repeated inputs keep
//!   landing on the node whose result cache holds them, so the
//!   cluster-wide hit count strictly beats the affinity-off spread;
//! - failover **hides a dying node**: killing a replica mid-pipeline
//!   surfaces zero client-visible failures;
//! - a **rolling hot-swap** marches retire/register across every
//!   replica under live traffic, again with zero failed requests.

use hetero_dnn::cluster::{Node, Router, RouterConfig, Topology};
use hetero_dnn::coordinator::protocol::{AsyncClient, Reply};
use hetero_dnn::coordinator::ModelSpec;
use hetero_dnn::runtime::Tensor;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// The three paper nets every node serves in the transparency test.
const GRAPHS: [&str; 3] = ["squeezenet", "mobilenetv2_05", "shufflenetv2_05"];

fn fire_spec(seed: u64) -> ModelSpec {
    ModelSpec::new("fire", "fire_full", "squeezenet").workers(1).seed(seed)
}

/// Receive one reply and panic on anything but a successful response.
fn recv_ok(client: &mut AsyncClient) -> hetero_dnn::coordinator::server::ClientResponse {
    match client.recv().expect("recv") {
        Reply::Response(r) => r,
        Reply::Error { code, message, .. } => panic!("client-visible failure: {code}: {message}"),
    }
}

#[test]
fn routed_answers_are_bit_identical_to_direct_for_every_graph() {
    let specs = || {
        GRAPHS
            .into_iter()
            .map(|g| ModelSpec::net(g).workers(1).seed(0))
            .collect::<Vec<_>>()
    };
    let topo = Topology::new();
    for _ in 0..3 {
        topo.add(Node::start(specs()).expect("cluster node"));
    }
    let router =
        Router::start("127.0.0.1:0", &topo.addrs(), RouterConfig::default()).expect("router");
    let direct_node = Node::start(specs()).expect("direct node");

    let mut routed = AsyncClient::connect(&router.addr).expect("router connect");
    let mut direct = AsyncClient::connect(&direct_node.addr()).expect("direct connect");
    assert_eq!(routed.models(), direct.models(), "router snapshots the replica model table");

    for graph in GRAPHS {
        let shape = routed
            .models()
            .iter()
            .find(|(name, _)| name == graph)
            .map(|(_, dims)| dims.clone())
            .expect("graph registered");
        for seed in 0..3u64 {
            let x = Tensor::randn(&shape, seed);
            let id_r = routed.submit(Some(graph), &x).expect("routed submit");
            let id_d = direct.submit(Some(graph), &x).expect("direct submit");
            let r = recv_ok(&mut routed);
            let d = recv_ok(&mut direct);
            assert_eq!((r.id, d.id), (id_r, id_d));
            assert_eq!((r.model.as_str(), d.model.as_str()), (graph, graph));
            assert_eq!(r.output.shape, d.output.shape, "{graph} seed {seed}: shape");
            assert_eq!(r.output.data, d.output.data, "{graph} seed {seed}: bit identity");
        }
    }
    router.stop();
}

#[test]
fn affinity_on_beats_affinity_off_on_cluster_cache_hits() {
    const K: u64 = 4;
    const ROUNDS: usize = 6;
    let spec = || fire_spec(0).cache(32);

    let mut hits = Vec::new();
    for affinity in [false, true] {
        let topo = Topology::new();
        for _ in 0..3 {
            topo.add(Node::start(vec![spec()]).expect("cluster node"));
        }
        let cfg = RouterConfig { affinity, ..RouterConfig::default() };
        let router = Router::start("127.0.0.1:0", &topo.addrs(), cfg).expect("router");
        let mut client = AsyncClient::connect(&router.addr).expect("router connect");
        let shape = client.models()[0].1.clone();
        let xs: Vec<Tensor> = (0..K).map(|s| Tensor::randn(&shape, s)).collect();
        // lockstep on purpose: replica loads are equal at every accept,
        // so the affinity-off arm shows its pure tie-rotation spread
        for _ in 0..ROUNDS {
            for x in &xs {
                client.submit(None, x).expect("submit");
                recv_ok(&mut client);
            }
        }
        let mut total = 0u64;
        for i in 0..3 {
            let engine = topo.engine(i).expect("alive");
            let metrics = engine.metrics("fire").expect("registered");
            total += metrics.lock().unwrap().cache_hits;
        }
        hits.push(total);
        router.stop();
    }
    let (off, on) = (hits[0], hits[1]);
    // with affinity, only each input's first sighting misses
    assert_eq!(on, (ROUNDS as u64 - 1) * K, "affinity-on must hit after the first round");
    assert!(
        on > off,
        "affinity-on hit count ({on}) must strictly beat affinity-off ({off})"
    );
}

#[test]
fn killing_a_node_mid_pipeline_loses_no_request() {
    const REQS: usize = 30;
    const DEPTH: usize = 6;
    let topo = Topology::new();
    for _ in 0..3 {
        topo.add(Node::start(vec![fire_spec(0)]).expect("cluster node"));
    }
    let router =
        Router::start("127.0.0.1:0", &topo.addrs(), RouterConfig::default()).expect("router");
    let mut client = AsyncClient::connect(&router.addr).expect("router connect");
    let shape = client.models()[0].1.clone();

    let (mut submitted, mut received, mut killed) = (0usize, 0usize, false);
    while received < REQS {
        while submitted < REQS && client.in_flight() < DEPTH {
            // distinct inputs so the rendezvous hash spreads traffic
            // across all three replicas, including the one about to die
            let x = Tensor::randn(&shape, submitted as u64);
            client.submit(None, &x).expect("submit");
            submitted += 1;
        }
        if !killed && received >= REQS / 3 {
            // mid-pipeline, with requests in flight: queued work drains
            // as model_retiring and the connection then drops — the
            // router must absorb both without a client-visible error
            assert!(topo.kill(0), "node 0 was alive");
            killed = true;
        }
        let r = recv_ok(&mut client);
        assert_eq!(r.output.shape.len(), client.models()[0].1.len());
        received += 1;
    }
    assert!(killed, "the kill must happen mid-stream");
    assert_eq!(received, REQS);
    router.stop();
}

#[test]
fn rolling_hot_swap_under_live_traffic_loses_no_request() {
    let topo = Arc::new(Topology::new());
    for _ in 0..3 {
        topo.add(Node::start(vec![fire_spec(0)]).expect("cluster node"));
    }
    let router =
        Router::start("127.0.0.1:0", &topo.addrs(), RouterConfig::default()).expect("router");
    let addr = router.addr;

    let stop = Arc::new(AtomicBool::new(false));
    let traffic = {
        let stop = stop.clone();
        std::thread::spawn(move || -> u64 {
            let mut client = AsyncClient::connect(&addr).expect("traffic connect");
            let shape = client.models()[0].1.clone();
            let mut served = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let x = Tensor::randn(&shape, served);
                client.submit(None, &x).expect("submit");
                recv_ok(&mut client);
                served += 1;
            }
            served
        })
    };

    // let traffic get going, then march the swap across the cluster
    std::thread::sleep(Duration::from_millis(50));
    let swapped = topo.rolling_swap("fire", &|| fire_spec(1)).expect("rolling swap");
    assert_eq!(swapped, 3, "every replica must be swapped");

    // traffic keeps flowing after the swap, against the new revision
    std::thread::sleep(Duration::from_millis(50));
    stop.store(true, Ordering::Relaxed);
    let served = traffic.join().expect("traffic thread");
    assert!(served > 0, "the traffic thread must have been served throughout");
    for i in 0..3 {
        let engine = topo.engine(i).expect("alive");
        assert_eq!(engine.models(), vec!["fire".to_string()], "replica {i} serves the new spec");
    }
    router.stop();
}
