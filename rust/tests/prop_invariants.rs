//! Property-based tests over the coordinator's invariants (routing,
//! batching math, cost algebra, device-model monotonicity, partition
//! algebra). The generator is a small in-tree xorshift PRNG (offline
//! build — no proptest; DESIGN.md §Offline): every property runs over a
//! few hundred randomized cases with a fixed seed, so failures reproduce.

use hetero_dnn::config::json;
use hetero_dnn::dhm::DhmModel;
use hetero_dnn::gpu::GpuModel;
use hetero_dnn::graph::{models, Activation, Layer, OpKind, TensorShape};
use hetero_dnn::link::{LinkModel, Precision};
use hetero_dnn::metrics::Cost;
use hetero_dnn::partition::{Planner, Step, Strategy};
use hetero_dnn::quant;
use hetero_dnn::runtime::Tensor;
use hetero_dnn::sched;

/// Deterministic xorshift64* PRNG.
struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Self {
        Rng(seed.max(1))
    }

    fn next(&mut self) -> u64 {
        self.0 ^= self.0 << 13;
        self.0 ^= self.0 >> 7;
        self.0 ^= self.0 << 17;
        self.0
    }

    /// Uniform in [lo, hi] inclusive.
    fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + (self.next() as usize) % (hi - lo + 1)
    }

    fn f32(&mut self) -> f32 {
        ((self.next() >> 11) as f64 / (1u64 << 53) as f64) as f32 * 2.0 - 1.0
    }
}

const CASES: usize = 300;

// ---------------------------------------------------------------------------
// graph invariants

#[test]
fn prop_conv_shape_inference_consistent() {
    let mut rng = Rng::new(1);
    for _ in 0..CASES {
        let h = rng.range(4, 64);
        let w = rng.range(4, 64);
        let ci = rng.range(1, 32);
        let k = [1, 3, 5, 7][rng.range(0, 3)];
        let s = rng.range(1, 2);
        let pad = k / 2;
        if h + 2 * pad < k || w + 2 * pad < k {
            continue;
        }
        let op = OpKind::Conv { k, stride: s, pad, cout: rng.range(1, 64), act: Activation::None };
        let o = op.infer(TensorShape::new(h, w, ci));
        // brute force: count valid window positions
        let count = |size: usize| (0..=(size + 2 * pad - k)).step_by(s).count();
        assert_eq!(o.h, count(h), "h: {h} k{k} s{s}");
        assert_eq!(o.w, count(w));
    }
}

#[test]
fn prop_gconv_macs_scale_with_groups() {
    let mut rng = Rng::new(2);
    for _ in 0..CASES {
        let g = [1, 2, 4][rng.range(0, 2)];
        let cig = rng.range(1, 8);
        let cog = rng.range(1, 8);
        let i = TensorShape::new(rng.range(4, 32), rng.range(4, 32), g * cig);
        let dense = Layer::new(
            OpKind::Conv { k: 3, stride: 1, pad: 1, cout: g * cog, act: Activation::None },
            i,
        );
        let grouped = Layer::new(
            OpKind::GConv { k: 3, stride: 1, groups: g, cout: g * cog, act: Activation::None },
            i,
        );
        assert_eq!(dense.macs(), grouped.macs() * g as u64);
    }
}

#[test]
fn prop_weight_count_matches_macs_per_position() {
    // for stride-1 SAME convs: macs == weights * positions
    let mut rng = Rng::new(3);
    for _ in 0..CASES {
        let i = TensorShape::new(rng.range(4, 32), rng.range(4, 32), rng.range(1, 16));
        let k = [1, 3, 5][rng.range(0, 2)];
        let l = Layer::new(
            OpKind::Conv { k, stride: 1, pad: k / 2, cout: rng.range(1, 16), act: Activation::None },
            i,
        );
        assert_eq!(l.macs(), l.weight_count() * (i.h * i.w) as u64);
    }
}

// ---------------------------------------------------------------------------
// cost algebra

#[test]
fn prop_cost_then_is_associative_and_monotone() {
    let mut rng = Rng::new(4);
    for _ in 0..CASES {
        let c = |r: &mut Rng| Cost::new(r.f32().abs() as f64, r.f32().abs() as f64);
        let (a, b, d) = (c(&mut rng), c(&mut rng), c(&mut rng));
        let l = a.then(b).then(d);
        let r = a.then(b.then(d));
        assert!((l.seconds - r.seconds).abs() < 1e-12);
        assert!((l.joules - r.joules).abs() < 1e-12);
        assert!(l.seconds >= a.seconds && l.joules >= a.joules);
    }
}

#[test]
fn prop_alongside_bounds() {
    let mut rng = Rng::new(5);
    for _ in 0..CASES {
        let a = Cost::new(rng.f32().abs() as f64, rng.f32().abs() as f64);
        let b = Cost::new(rng.f32().abs() as f64, rng.f32().abs() as f64);
        let p = a.alongside(b);
        assert!(p.seconds >= a.seconds.max(b.seconds) - 1e-15);
        assert!(p.seconds <= a.seconds + b.seconds + 1e-15);
        assert!((p.joules - (a.joules + b.joules)).abs() < 1e-12);
        // commutative
        let q = b.alongside(a);
        assert!((p.seconds - q.seconds).abs() < 1e-15);
    }
}

// ---------------------------------------------------------------------------
// quantization

#[test]
fn prop_quant_roundtrip_bounded() {
    let mut rng = Rng::new(6);
    for _ in 0..100 {
        let n = rng.range(1, 256);
        let xs: Vec<f32> = (0..n).map(|_| rng.f32() * 10.0).collect();
        let s = quant::scale_for(&xs);
        let rt = quant::fake_quant(&xs, s);
        let bound = quant::roundtrip_error_bound(s) + 1e-6;
        for (a, b) in xs.iter().zip(&rt) {
            assert!((a - b).abs() <= bound, "|{a} - {b}| > {bound}");
        }
    }
}

#[test]
fn prop_quant_idempotent() {
    // quantizing an already-quantized tensor changes nothing
    let mut rng = Rng::new(7);
    for _ in 0..100 {
        let xs: Vec<f32> = (0..64).map(|_| rng.f32() * 5.0).collect();
        let s = quant::scale_for(&xs);
        let once = quant::fake_quant(&xs, s);
        let twice = quant::fake_quant(&once, s);
        assert_eq!(once, twice);
    }
}

// ---------------------------------------------------------------------------
// device-model monotonicity

#[test]
fn prop_dhm_resources_monotone() {
    let dhm = DhmModel::default();
    let mut rng = Rng::new(8);
    for _ in 0..CASES {
        let i = TensorShape::new(rng.range(8, 64), rng.range(8, 64), rng.range(1, 8));
        let n = rng.range(1, 32);
        let mk = |k: usize, n: usize, i: TensorShape| {
            Layer::new(OpKind::Conv { k, stride: 1, pad: k / 2, cout: n, act: Activation::None }, i)
        };
        let a = dhm.resources(&mk(3, n, i)).unwrap();
        let b = dhm.resources(&mk(3, n * 2, i)).unwrap();
        let c = dhm.resources(&mk(5, n, i)).unwrap();
        assert!(b.alms >= a.alms && b.macs_spatial == 2 * a.macs_spatial);
        assert!(c.macs_spatial > a.macs_spatial);
    }
}

#[test]
fn prop_dhm_split_is_a_cliff() {
    // max_feasible_split: g fits, g+1 does not (when g < Ci)
    let dhm = Planner::default().sdhm();
    let mut rng = Rng::new(9);
    for _ in 0..60 {
        let ci = rng.range(2, 64);
        let l = Layer::new(
            OpKind::Conv { k: 3, stride: 1, pad: 1, cout: rng.range(8, 128), act: Activation::None },
            TensorShape::new(rng.range(8, 56), rng.range(8, 56), ci),
        );
        let g = dhm.max_feasible_split(&l);
        if g == 0 || g == ci {
            continue;
        }
        let mut fit_probe = l;
        fit_probe.input.c = g;
        assert!(dhm.resources(&fit_probe).map(|u| dhm.check_fit(u).is_ok()).unwrap());
        let mut over_probe = l;
        over_probe.input.c = g + 1;
        assert!(!dhm.resources(&over_probe).map(|u| dhm.check_fit(u).is_ok()).unwrap());
    }
}

#[test]
fn prop_gpu_latency_monotone_in_work() {
    let gpu = GpuModel::default();
    let mut rng = Rng::new(10);
    for _ in 0..CASES {
        let i = TensorShape::new(rng.range(8, 64), rng.range(8, 64), rng.range(1, 32));
        let n = rng.range(1, 64);
        let mk = |n: usize| {
            Layer::new(OpKind::Conv { k: 3, stride: 1, pad: 1, cout: n, act: Activation::None }, i)
        };
        assert!(gpu.latency(&mk(2 * n)) >= gpu.latency(&mk(n)) - 1e-15);
        let p = gpu.power(&mk(n));
        assert!(p >= gpu.dev.p_idle && p <= gpu.dev.p_max);
    }
}

#[test]
fn prop_link_transfer_additive_and_monotone() {
    let link = LinkModel::default();
    let mut rng = Rng::new(11);
    for _ in 0..CASES {
        let a = rng.range(1, 1 << 20);
        let b = rng.range(1, 1 << 20);
        let ta = link.transfer(a, Precision::Int8);
        let tb = link.transfer(b, Precision::Int8);
        let tab = link.transfer(a + b, Precision::Int8);
        // one transfer beats two (setup amortization)
        assert!(tab.seconds <= ta.seconds + tb.seconds + 1e-15);
        assert!(tab.seconds >= ta.seconds.max(tb.seconds) - 1e-15);
    }
}

// ---------------------------------------------------------------------------
// partition / scheduling invariants

#[test]
fn prop_fire_split_shares_partition_the_layer() {
    let p = Planner::default();
    let mut rng = Rng::new(12);
    for _ in 0..60 {
        let h = rng.range(8, 56);
        let ci = rng.range(32, 256);
        let s = rng.range(8, 64);
        let e = rng.range(16, 128);
        let m = models::fire("f", TensorShape::new(h, h, ci), s, e, e);
        let Ok(plan) = p.plan_gconv_split(&m) else { continue };
        // the parallel step's two expand3 halves cover all s input channels
        let Step::Parallel { gpu, fpga } = &plan.steps[1] else { panic!() };
        let gpu_e3 = gpu.iter().find_map(|st| match st {
            Step::Gpu { layer, label, .. } if label.contains("expand3") => Some(layer),
            _ => None,
        });
        let fpga_e3 = fpga.iter().find_map(|st| match st {
            Step::Fpga { layers, .. } => Some(&layers[0]),
            _ => None,
        });
        let (Some(g), Some(f)) = (gpu_e3, fpga_e3) else { continue };
        assert_eq!(f.input.c + g.input.c, s, "input channels partitioned");
        let co_f = f.output.c;
        let co_g = g.output.c;
        assert_eq!(co_f + co_g, e, "output filters partitioned");
    }
}

#[test]
fn prop_schedule_makespan_bounds() {
    // makespan >= each resource busy time; <= serialization of all steps
    let p = Planner::default();
    let mut rng = Rng::new(13);
    let graphs = models::all_models();
    for _ in 0..60 {
        let g = &graphs[rng.range(0, 2)];
        let m = &g.modules[rng.range(0, g.modules.len() - 1)];
        for strat in [Strategy::GpuOnly, Strategy::Paper] {
            let Ok(plan) = p.plan_module(m, strat) else { continue };
            let ev = sched::evaluate(&plan);
            let serial: f64 = ev.timeline.iter().map(|t| t.end - t.start).sum();
            assert!(ev.total.seconds <= serial + 1e-12);
            for busy in [ev.gpu_busy, ev.fpga_busy, ev.link_busy] {
                assert!(ev.total.seconds >= busy - 1e-12);
            }
            // energy >= sum of step energies (idle charges only add)
            let step_e: f64 = ev.timeline.iter().map(|t| t.joules).sum();
            assert!(ev.total.joules >= step_e - 1e-12);
        }
    }
}

#[test]
fn prop_paper_plan_never_regresses_energy() {
    // plan_model_paper's acceptance criterion, fuzzed over resolutions
    let p = Planner::default();
    let mut rng = Rng::new(14);
    for _ in 0..12 {
        let res = [96, 112, 128, 160, 192, 224][rng.range(0, 5)];
        for g in [models::squeezenet(res), models::mobilenetv2_05(res), models::shufflenetv2_05(res)] {
            let base = sched::evaluate_model_with(
                &p.plan_model(&g, Strategy::GpuOnly),
                sched::IdleParams::paper(),
            );
            let het =
                sched::evaluate_model_with(&p.plan_model_paper(&g), sched::IdleParams::paper());
            assert!(
                het.total.joules <= base.total.joules + 1e-12,
                "{} @{res}: {} > {}",
                g.name,
                het.total.joules,
                base.total.joules
            );
        }
    }
}

// ---------------------------------------------------------------------------
// tensor + json substrate properties

#[test]
fn prop_tensor_concat_slice_roundtrip() {
    let mut rng = Rng::new(15);
    for _ in 0..100 {
        let h = rng.range(1, 8);
        let ca = rng.range(1, 16);
        let cb = rng.range(1, 16);
        let a = Tensor::randn(&[1, h, h, ca], rng.next());
        let b = Tensor::randn(&[1, h, h, cb], rng.next());
        let c = a.concat_last(&b);
        assert_eq!(c.slice_last(0, ca), a);
        assert_eq!(c.slice_last(ca, ca + cb), b);
    }
}

#[test]
fn prop_channel_shuffle_is_permutation() {
    let mut rng = Rng::new(16);
    for _ in 0..100 {
        let g = [2, 3, 4][rng.range(0, 2)];
        let c = g * rng.range(1, 8);
        let t = Tensor::randn(&[1, 2, 2, c], rng.next());
        let s = t.channel_shuffle(g);
        let mut x = t.data.clone();
        let mut y = s.data.clone();
        x.sort_by(f32::total_cmp);
        y.sort_by(f32::total_cmp);
        assert_eq!(x, y);
    }
}

#[test]
fn prop_json_roundtrip() {
    // generate random JSON, serialize, parse, compare
    fn gen(rng: &mut Rng, depth: usize) -> json::Json {
        match if depth == 0 { rng.range(0, 2) } else { rng.range(0, 4) } {
            0 => json::Json::Num((rng.range(0, 100000) as f64) / 8.0),
            1 => json::Json::Str(format!("s{}", rng.range(0, 999))),
            2 => json::Json::Bool(rng.range(0, 1) == 0),
            3 => json::Json::Arr((0..rng.range(0, 4)).map(|_| gen(rng, depth - 1)).collect()),
            _ => json::Json::Obj(
                (0..rng.range(0, 4)).map(|i| (format!("k{i}"), gen(rng, depth - 1))).collect(),
            ),
        }
    }
    fn ser(v: &json::Json, out: &mut String) {
        match v {
            json::Json::Null => out.push_str("null"),
            json::Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            json::Json::Num(n) => out.push_str(&format!("{n}")),
            json::Json::Str(s) => out.push_str(&format!("{s:?}")),
            json::Json::Arr(a) => {
                out.push('[');
                for (i, x) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    ser(x, out);
                }
                out.push(']');
            }
            json::Json::Obj(o) => {
                out.push('{');
                for (i, (k, x)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(&format!("{k:?}:"));
                    ser(x, out);
                }
                out.push('}');
            }
        }
    }
    let mut rng = Rng::new(17);
    for _ in 0..200 {
        let v = gen(&mut rng, 3);
        let mut text = String::new();
        ser(&v, &mut text);
        let parsed = json::parse(&text).unwrap_or_else(|e| panic!("{text}: {e}"));
        assert_eq!(parsed, v, "{text}");
    }
}

// ---------------------------------------------------------------------------
// admission + slot invariants (shared asserters with the model checker:
// tests/model_check.rs explores the *schedule* space with these same
// ledgers; the properties here explore the *op-mix* space — random
// accept/reject/cache-hit/budget-cancel/retire sequences against the
// real AdmissionController)

use hetero_dnn::check::invariants::{ReplyLedger, SlotLedger};
use hetero_dnn::coordinator::admission::{Admission, AdmissionConfig, AdmissionController};
use std::collections::VecDeque;
use std::sync::atomic::Ordering;
use std::time::Duration;

#[test]
fn prop_slot_take_return_balances_over_random_op_sequences() {
    for case in 0..CASES {
        let mut rng = Rng::new(0x51077 + case as u64);
        let ctl = AdmissionController::new(AdmissionConfig {
            deadline: Duration::from_secs(1),
            max_in_flight: rng.range(1, 6) as u64,
            alpha: 0.2,
        });
        let budget = rng.range(1, 4) as u64;
        let mut slots = SlotLedger::new();
        let mut replies = ReplyLedger::new();
        let mut queue: VecDeque<u64> = VecDeque::new();
        let mut cached: Vec<u64> = Vec::new();
        let mut in_flight_model = 0u64;
        let mut retired = false;
        let mut produced = 0u64;

        for _ in 0..rng.range(5, 40) {
            match rng.range(0, 3) {
                // submit: the engine front door in order — registry,
                // cache, shared admission, per-model budget, pool queue
                0 => {
                    let tag = produced;
                    produced += 1;
                    if retired {
                        replies.record(tag); // unknown-model fast path
                        continue;
                    }
                    if cached.contains(&(tag % 5)) {
                        replies.record(tag); // cache hit: no slot taken
                        continue;
                    }
                    match ctl.admit() {
                        Admission::Reject { .. } => replies.record(tag),
                        Admission::Accept => {
                            slots.take(tag);
                            in_flight_model += 1;
                            if in_flight_model > budget {
                                // budget-cancel: shared slot MUST return
                                in_flight_model -= 1;
                                ctl.cancel();
                                slots.put(tag);
                                replies.record(tag);
                            } else {
                                queue.push_back(tag);
                            }
                        }
                    }
                }
                // a worker completes the queue head
                1 => {
                    if let Some(tag) = queue.pop_front() {
                        in_flight_model -= 1;
                        ctl.complete(Duration::from_millis(1));
                        slots.put(tag);
                        cached.push(tag % 5);
                        replies.record(tag);
                    }
                }
                // occasionally retire: drain the queue with replies
                _ => {
                    if !retired && rng.range(0, 4) == 0 {
                        retired = true;
                        while let Some(tag) = queue.pop_front() {
                            in_flight_model -= 1;
                            ctl.complete(Duration::from_millis(1));
                            slots.put(tag);
                            replies.record(tag);
                        }
                    }
                }
            }
            // the checker's step invariants, after every op
            slots.at_most_once().unwrap_or_else(|e| panic!("case {case}: {e}"));
            replies.at_most_once().unwrap_or_else(|e| panic!("case {case}: {e}"));
            assert_eq!(ctl.in_flight() as i64, slots.outstanding(), "case {case}");
            assert!(in_flight_model <= budget, "case {case}: budget cap holds");
        }

        // engine shutdown: drain to quiescence
        while let Some(tag) = queue.pop_front() {
            in_flight_model -= 1;
            ctl.complete(Duration::from_millis(1));
            slots.put(tag);
            replies.record(tag);
        }
        assert_eq!(in_flight_model, 0, "case {case}");
        slots.balanced().unwrap_or_else(|e| panic!("case {case}: {e}"));
        replies.exactly_once(produced).unwrap_or_else(|e| panic!("case {case}: {e}"));
        assert_eq!(ctl.in_flight(), 0, "case {case}: controller quiescent");
    }
}

#[test]
fn prop_budget_cancel_nets_out_of_admitted_counter() {
    for case in 0..CASES {
        let mut rng = Rng::new(0xBD6E7 + case as u64);
        let ctl = AdmissionController::new(AdmissionConfig {
            deadline: Duration::from_secs(10),
            max_in_flight: 1_000,
            alpha: 0.5,
        });
        let budget = rng.range(1, 3) as u64;
        let mut in_flight = 0u64;
        let mut completed = 0u64;
        let mut cancelled = 0u64;
        for _ in 0..rng.range(1, 60) {
            if rng.range(0, 1) == 0 {
                // submit against the per-model budget
                assert!(matches!(ctl.admit(), Admission::Accept), "cap 1000 never rejects");
                in_flight += 1;
                if in_flight > budget {
                    in_flight -= 1;
                    ctl.cancel();
                    cancelled += 1;
                }
            } else if in_flight > 0 {
                in_flight -= 1;
                completed += 1;
                ctl.complete(Duration::from_micros(rng.range(10, 500) as u64));
            }
            assert_eq!(ctl.in_flight(), in_flight, "case {case}: gauge tracks in-flight");
            assert!(in_flight <= budget, "case {case}: budget cap holds");
        }
        // every budget cancel was net-neutral on the admitted counter
        assert_eq!(
            ctl.admitted.load(Ordering::Relaxed),
            in_flight + completed,
            "case {case}: admitted counter nets out {cancelled} cancel(s)"
        );
        while in_flight > 0 {
            in_flight -= 1;
            ctl.complete(Duration::from_millis(1));
        }
        assert_eq!(ctl.in_flight(), 0, "case {case}: controller quiescent");
    }
}

// ---------------------------------------------------------------------------
// traffic-lab schedule builder (workloads::scenario, DESIGN.md §13)

use hetero_dnn::coordinator::Priority;
use hetero_dnn::workloads::{build_schedule, InputMix, RateShape, ScenarioSpec};

#[test]
fn prop_same_seed_builds_byte_identical_schedule() {
    let mut rng = Rng::new(0x7F1C);
    for spec in ScenarioSpec::all() {
        for _ in 0..24 {
            let seed = rng.next();
            let models = rng.range(1, 4);
            let dur = Duration::from_millis(rng.range(100, 600) as u64);
            let a = build_schedule(&spec, models, seed, dur);
            let b = build_schedule(&spec, models, seed, dur);
            assert_eq!(a, b, "{}: same seed must rebuild identically", spec.name);
            assert_eq!(a.fingerprint(), b.fingerprint(), "{}", spec.name);
            let c = build_schedule(&spec, models, seed ^ 1, dur);
            assert_ne!(a.fingerprint(), c.fingerprint(), "{}: seed must matter", spec.name);
        }
    }
}

#[test]
fn prop_arrival_count_within_analytic_rate_bounds() {
    // gaps are jittered over [0.5, 1.5) of the instantaneous mean gap,
    // and the instantaneous rate never leaves [base_rate, peak_rate], so
    // the arrival count is bracketed by base·span/1.5 and peak·span/0.5
    let mut rng = Rng::new(0x7F2C);
    for spec in ScenarioSpec::all() {
        for _ in 0..12 {
            let seed = rng.next();
            let secs = rng.range(200, 800) as f64 / 1000.0;
            let s = build_schedule(&spec, 2, seed, Duration::from_secs_f64(secs));
            let n = s.arrivals.len() as f64;
            let lo = spec.base_rate * secs / 1.5 - 2.0;
            let hi = spec.peak_rate * secs / 0.5 + 1.0;
            assert!(n >= lo, "{}: {n} arrivals under floor {lo}", spec.name);
            assert!(n <= hi, "{}: {n} arrivals over ceiling {hi}", spec.name);
        }
    }
}

#[test]
fn prop_flat_schedule_is_prefix_closed_open_loop() {
    // the structural form of the open-loop guarantee: the draw stream is
    // a pure function of (spec, seed) — for duration-independent rate
    // shapes a shorter replay window is literally a prefix of a longer one
    let mut rng = Rng::new(0x7F3C);
    for spec in ScenarioSpec::all().into_iter().filter(|s| s.shape == RateShape::Flat) {
        for _ in 0..12 {
            let seed = rng.next();
            let short = build_schedule(&spec, 3, seed, Duration::from_millis(250));
            let long = build_schedule(&spec, 3, seed, Duration::from_millis(750));
            assert!(long.arrivals.len() >= short.arrivals.len(), "{}", spec.name);
            assert_eq!(
                short.arrivals.as_slice(),
                &long.arrivals[..short.arrivals.len()],
                "{}: short schedule must be a prefix of the long one",
                spec.name
            );
            let window = Duration::from_millis(250);
            let in_window = long.arrivals.iter().filter(|a| a.at < window).count();
            assert_eq!(in_window, short.arrivals.len(), "{}: prefix spans the window", spec.name);
        }
    }
}

#[test]
fn prop_arrival_stream_structurally_sound() {
    let mut rng = Rng::new(0x7F4C);
    for spec in ScenarioSpec::all() {
        let seed = rng.next();
        let models = rng.range(2, 4);
        let s = build_schedule(&spec, models, seed, Duration::from_millis(500));
        assert!(!s.arrivals.is_empty(), "{}: empty schedule", spec.name);
        for w in s.arrivals.windows(2) {
            assert!(w[0].at < w[1].at, "{}: arrivals strictly ordered", spec.name);
        }
        for a in &s.arrivals {
            assert!(a.model < models, "{}: model index out of range", spec.name);
            assert!(a.at < s.duration, "{}: arrival outside the window", spec.name);
            assert_eq!(
                a.priority == Priority::High,
                a.deadline.is_some(),
                "{}: deadline-bearing arrivals (and only those) ride High",
                spec.name
            );
            if let InputMix::Shared { distinct } = spec.inputs {
                assert!(a.input_seed < u64::from(distinct), "{}: seed pool", spec.name);
            }
        }
    }
}

#[test]
fn prop_cache_hostile_inputs_never_repeat() {
    let spec = ScenarioSpec::named("cache_hostile").expect("registered");
    for seed in [1u64, 99, 0xABCD_EF01] {
        let s = build_schedule(&spec, 2, seed, Duration::from_millis(800));
        let mut seen = std::collections::BTreeSet::new();
        for a in &s.arrivals {
            assert!(seen.insert(a.input_seed), "seed {seed}: input digest repeated");
        }
    }
}

// ---------------------------------------------------------------------------
// device arbitration (runtime::arbiter, DESIGN.md §14 — the op-mix
// companion to check::scenarios::arbiter_grants_exactly_once, which
// explores the *schedule* space over a fixed mix; here the mix itself
// is random and the ledger must balance after every op)

use hetero_dnn::runtime::arbiter::{
    ArbiterCore, ArbiterEffect, ArbiterEvent, DeviceId, TenantId, Ticket,
};

#[test]
fn prop_arbiter_random_op_mixes_settle_every_ticket() {
    use std::collections::{BTreeMap, BTreeSet};

    /// Fold one step's effects into the model ledgers, holding the
    /// grant-at-most-once / grant-xor-cancel contract as they land.
    fn apply(
        case: usize,
        fx: Vec<ArbiterEffect>,
        granted: &mut BTreeSet<u64>,
        cancelled: &mut BTreeSet<u64>,
        holding: &mut Vec<Ticket>,
    ) {
        for f in fx {
            match f {
                ArbiterEffect::Granted { ticket, .. } => {
                    assert!(granted.insert(ticket.0), "case {case}: granted twice");
                    assert!(!cancelled.contains(&ticket.0), "case {case}: grant after cancel");
                    holding.push(ticket);
                }
                ArbiterEffect::Cancelled { ticket, .. } => {
                    assert!(cancelled.insert(ticket.0), "case {case}: cancelled twice");
                    assert!(!granted.contains(&ticket.0), "case {case}: cancel after grant");
                }
            }
        }
    }

    for case in 0..CASES {
        let mut rng = Rng::new(0xA4B1 + case as u64);
        let tenants = rng.range(1, 3);
        let mut core = ArbiterCore::new();
        let mut granted: BTreeSet<u64> = BTreeSet::new();
        let mut cancelled: BTreeSet<u64> = BTreeSet::new();
        let mut released: BTreeSet<u64> = BTreeSet::new();
        let mut holding: Vec<Ticket> = Vec::new();
        let mut device_of: BTreeMap<u64, DeviceId> = BTreeMap::new();
        let mut submitted: Vec<Ticket> = Vec::new();
        let mut retired = vec![false; tenants];
        let mut next = 0u64;

        for _ in 0..rng.range(10, 80) {
            match rng.range(0, 8) {
                0..=4 => {
                    let tenant = rng.range(0, tenants - 1);
                    if retired[tenant] {
                        continue;
                    }
                    let device = DeviceId::ALL[rng.range(0, 2)];
                    let ticket = Ticket(next);
                    next += 1;
                    submitted.push(ticket);
                    device_of.insert(ticket.0, device);
                    let fx = core.step(ArbiterEvent::Request {
                        ticket,
                        tenant: TenantId(tenant as u64),
                        device,
                        priority: rng.range(0, 2) as u8,
                    });
                    apply(case, fx, &mut granted, &mut cancelled, &mut holding);
                }
                5..=6 => {
                    if holding.is_empty() {
                        continue;
                    }
                    let t = holding.swap_remove(rng.range(0, holding.len() - 1));
                    released.insert(t.0);
                    let fx = core.step(ArbiterEvent::Release { ticket: t });
                    apply(case, fx, &mut granted, &mut cancelled, &mut holding);
                }
                _ => {
                    let tenant = rng.range(0, tenants - 1);
                    if retired[tenant] {
                        continue;
                    }
                    retired[tenant] = true;
                    let fx = core.step(ArbiterEvent::Retire { tenant: TenantId(tenant as u64) });
                    apply(case, fx, &mut granted, &mut cancelled, &mut holding);
                }
            }
            // the busy/idle ledger balances after every op: each grant is
            // either released or still held, and each device's holder is
            // exactly the one held ticket targeting it
            assert_eq!(granted.len(), released.len() + holding.len(), "case {case}");
            for device in DeviceId::ALL {
                let held: Vec<Ticket> =
                    holding.iter().copied().filter(|t| device_of[&t.0] == device).collect();
                match core.holder(device) {
                    Some((t, _)) => {
                        assert_eq!(held, vec![t], "case {case}: {} holder", device.name());
                    }
                    None => {
                        assert!(held.is_empty(), "case {case}: phantom {} hold", device.name());
                    }
                }
            }
        }

        // drain: releasing every hold must grant every queued survivor
        while let Some(t) = holding.pop() {
            released.insert(t.0);
            let fx = core.step(ArbiterEvent::Release { ticket: t });
            apply(case, fx, &mut granted, &mut cancelled, &mut holding);
        }
        assert!(core.quiescent(), "case {case}: core must drain to quiescence");
        assert_eq!(granted.len() + cancelled.len(), submitted.len(), "case {case}");
        for t in &submitted {
            let settled = granted.contains(&t.0) ^ cancelled.contains(&t.0);
            assert!(settled, "case {case}: {t:?} not settled exactly once");
        }
        assert!(granted.iter().all(|t| released.contains(t)), "case {case}: unreleased grant");
    }
}

#[test]
fn prop_arbiter_grants_priority_then_fifo() {
    // single device, random request/release mix: the grant after each
    // release must be the earliest-arrived ticket of the highest waiting
    // priority — no later same-priority arrival ever overtakes
    for case in 0..CASES {
        let mut rng = Rng::new(0xF1F0 + case as u64);
        let mut core = ArbiterCore::new();
        let mut expected: [VecDeque<Ticket>; 2] = [VecDeque::new(), VecDeque::new()];
        let mut holder: Option<Ticket> = None;
        let mut next = 0u64;
        for _ in 0..rng.range(10, 60) {
            if rng.range(0, 1) == 0 {
                let prio = rng.range(0, 1);
                let ticket = Ticket(next);
                next += 1;
                let fx = core.step(ArbiterEvent::Request {
                    ticket,
                    tenant: TenantId(ticket.0 % 2),
                    device: DeviceId::Gpu,
                    priority: prio as u8,
                });
                if holder.is_none() && expected[0].is_empty() && expected[1].is_empty() {
                    assert_eq!(fx.len(), 1, "case {case}: free device grants immediately");
                    holder = Some(ticket);
                } else {
                    assert!(fx.is_empty(), "case {case}: busy device queues");
                    expected[prio].push_back(ticket);
                }
            } else if let Some(t) = holder.take() {
                let fx = core.step(ArbiterEvent::Release { ticket: t });
                match expected[1].pop_front().or_else(|| expected[0].pop_front()) {
                    Some(w) => {
                        let grant = ArbiterEffect::Granted {
                            ticket: w,
                            tenant: TenantId(w.0 % 2),
                            device: DeviceId::Gpu,
                        };
                        assert_eq!(fx, vec![grant], "case {case}: priority then FIFO");
                        holder = Some(w);
                    }
                    None => assert!(fx.is_empty(), "case {case}: empty queue grants nothing"),
                }
            }
            // the core's own grant-order view agrees with the model queue
            let model: Vec<Ticket> =
                expected[1].iter().chain(expected[0].iter()).copied().collect();
            assert_eq!(core.queued(DeviceId::Gpu), model, "case {case}");
        }
    }
}
