//! Integration tests: the traffic lab end to end (DESIGN.md §13).
//!
//! Short deterministic replays of every named scenario against a live
//! two-model [`Engine`] pin the ISSUE 8 acceptance criteria: the
//! accounting identity (`submitted == served + shed + rejected +
//! errors` — zero lost or duplicated replies), shedding confined to
//! past-deadline work, [`SloReport`] totals reconciling with the
//! engine's own metrics, bit-identical reports for equal seeds, the
//! adaptive controller strictly lifting flash-crowd SLO attainment over
//! the controller-off baseline, and slow-loris connections leaving
//! well-behaved sibling connections fully served.
//!
//! [`Engine`]: hetero_dnn::coordinator::Engine
//! [`SloReport`]: hetero_dnn::workloads::SloReport

use hetero_dnn::coordinator::server::Server;
use hetero_dnn::coordinator::{EngineBuilder, EngineHandle, ModelSpec, Placement};
use hetero_dnn::graph::models;
use hetero_dnn::partition::{Planner, Strategy};
use hetero_dnn::sched;
use hetero_dnn::workloads::{
    build_schedule, replay_endpoint, replay_engine, stall_connections, ControllerConfig,
    DeadlineMix, Pacing, ReplayConfig, ScenarioSpec, SloReport,
};
use std::time::Duration;

/// The standard two-model replay target: cheap module artifacts (the
/// simulated costs come from the full cost graphs), result caches on so
/// [`hetero_dnn::workloads::InputMix::Shared`] scenarios exercise hits.
fn lab_engine() -> EngineHandle {
    EngineBuilder::new()
        .max_wait(Duration::ZERO)
        .model(ModelSpec::new("fire", "fire_full", "squeezenet").cache(64))
        .model(ModelSpec::new("bottleneck", "bottleneck_full", "mobilenetv2_05").cache(64))
        .build()
        .expect("engine")
}

/// A graph's simulated per-image latency under `strategy`, microseconds —
/// exactly what the engine bills each non-cached request in virtual
/// replays (same planner, same evaluator).
fn sim_us(graph: &str, strategy: Strategy) -> u64 {
    let g = models::by_name(graph, 224).expect("paper graph");
    let plan = Planner::default().plan_model(&g, strategy);
    (sched::evaluate_model(&plan).total.seconds * 1e6).round() as u64
}

#[test]
fn all_named_scenarios_replay_clean_against_a_two_model_engine() {
    for spec in ScenarioSpec::all() {
        let handle = lab_engine();
        let engine = handle.engine.clone();
        let schedule =
            build_schedule(&spec, engine.models().len(), 42, Duration::from_millis(300));
        let report = replay_engine(&engine, &schedule, &ReplayConfig::default());

        assert_eq!(report.submitted, schedule.arrivals.len() as u64, "{}", spec.name);
        assert_eq!(
            report.submitted,
            report.served + report.shed + report.rejected + report.errors,
            "{}: accounting identity",
            spec.name
        );
        assert_eq!(report.errors, 0, "{}: no lost replies", spec.name);
        assert_eq!(report.rejected, 0, "{}: nothing rejected without a controller", spec.name);
        assert!(report.within_slo <= report.served, "{}", spec.name);

        // shedding is confined to deadline-bearing arrivals
        let deadline_arrivals =
            schedule.arrivals.iter().filter(|a| a.deadline.is_some()).count() as u64;
        assert!(report.shed <= deadline_arrivals, "{}: shed only past-deadline work", spec.name);
        if spec.deadlines == DeadlineMix::None {
            assert_eq!(report.shed, 0, "{}: nothing to shed without deadlines", spec.name);
        } else {
            assert!(deadline_arrivals > 0, "{}: scenario must carry deadlines", spec.name);
        }

        // reconcile with the engine's own books: everything the driver
        // counted as served or virtually shed was answered exactly once
        // (executed or cache hit), and nothing failed engine-side
        let mut answered = 0u64;
        for m in engine.models() {
            let metrics = engine.metrics(&m).expect("registered");
            let mm = metrics.lock().unwrap();
            answered += mm.served + mm.cache_hits;
            assert_eq!(mm.errors, 0, "{}: engine-side errors", spec.name);
        }
        assert_eq!(
            answered,
            report.served + report.shed,
            "{}: report totals reconcile with engine metrics",
            spec.name
        );
        drop(engine);
        handle.shutdown();
    }
}

#[test]
fn same_seed_replays_bit_identical_reports() {
    let spec = ScenarioSpec::named("flash_crowd").expect("registered");
    let run = || -> (u64, SloReport) {
        let handle = lab_engine();
        let engine = handle.engine.clone();
        let schedule =
            build_schedule(&spec, engine.models().len(), 7, Duration::from_millis(250));
        let cfg = ReplayConfig {
            controller: Some(ControllerConfig::default()),
            ..ReplayConfig::default()
        };
        let report = replay_engine(&engine, &schedule, &cfg);
        let fp = schedule.fingerprint();
        drop(engine);
        handle.shutdown();
        (fp, report)
    };
    let (fp_a, a) = run();
    let (fp_b, b) = run();
    assert_eq!(fp_a, fp_b, "schedule must be a pure function of (scenario, seed)");
    assert_eq!(a, b, "virtual replay must be deterministic, field for field");
    assert_eq!(a.fingerprint(), b.fingerprint(), "report fingerprints must agree");
}

#[test]
fn controller_lifts_flash_crowd_slo_attainment() {
    // place the SLO between the gpu-only and paper-plan simulated
    // latencies: the baseline placement cannot meet it, the controller's
    // fast placement always does
    let slow = sim_us("squeezenet", Strategy::GpuOnly);
    let fast = sim_us("squeezenet", Strategy::Paper);
    assert!(fast < slow, "paper plan must beat gpu-only on squeezenet latency");
    let slo = (fast + slow) / 2;
    let spec = ScenarioSpec::named("flash_crowd").expect("registered");

    let mut attainment = Vec::new();
    for controller_on in [false, true] {
        let handle = EngineBuilder::new()
            .max_wait(Duration::ZERO)
            .model(ModelSpec::new("squeeze", "fire_full", "squeezenet").strategy(Strategy::GpuOnly))
            .build()
            .expect("engine");
        let engine = handle.engine.clone();
        let schedule = build_schedule(&spec, 1, 11, Duration::from_millis(300));
        let cfg = ReplayConfig {
            slo_p99_us: slo,
            controller: controller_on.then(|| ControllerConfig {
                slo_p99_us: slo,
                // hold the fast placement for the whole replay: this test
                // is about attainment, the flap guard has its own tests
                clear_ticks: 1_000,
                hysteresis: Duration::from_millis(200),
                ..ControllerConfig::default()
            }),
            ..ReplayConfig::default()
        };
        let report = replay_engine(&engine, &schedule, &cfg);
        assert_eq!(
            report.submitted,
            report.served + report.shed + report.rejected + report.errors,
            "accounting identity (controller {controller_on})"
        );
        if controller_on {
            assert!(report.controller_flips >= 1, "controller must flip: {report}");
            assert_eq!(
                engine.placement("squeeze"),
                Some(Placement::Hetero),
                "the flip re-specs the model onto the hetero pipeline"
            );
            assert!(report.joules_per_inference > 0.0, "hetero lanes meter energy");
        } else {
            assert_eq!(report.controller_flips, 0, "no controller, no flips");
        }
        attainment.push(report.attainment());
        drop(engine);
        handle.shutdown();
    }
    assert!(
        attainment[1] > attainment[0],
        "controller-on must strictly beat controller-off on flash-crowd SLO attainment \
         (off {:.4} vs on {:.4})",
        attainment[0],
        attainment[1]
    );
}

#[test]
fn wall_pacing_preserves_the_accounting_identity() {
    let handle = lab_engine();
    let engine = handle.engine.clone();
    let spec = ScenarioSpec::named("zipf_models").expect("registered");
    let schedule = build_schedule(&spec, engine.models().len(), 5, Duration::from_millis(250));
    let cfg = ReplayConfig {
        pacing: Pacing::Wall { speedup: 4.0 },
        // wall quantiles are machine-dependent; this test pins accounting
        slo_p99_us: 1_000_000,
        ..ReplayConfig::default()
    };
    let report = replay_engine(&engine, &schedule, &cfg);
    assert_eq!(
        report.submitted,
        report.served + report.shed + report.rejected + report.errors,
        "accounting identity: {report}"
    );
    assert_eq!(report.errors, 0, "no replies may be lost: {report}");
    assert!(report.served > 0, "{report}");
    drop(engine);
    handle.shutdown();
}

#[test]
fn slow_loris_connections_do_not_starve_well_behaved_siblings() {
    let handle = lab_engine();
    let engine = handle.engine.clone();
    let server = Server::start("127.0.0.1:0", engine.clone()).expect("server");
    let spec = ScenarioSpec::named("slow_loris").expect("registered");
    let schedule = build_schedule(&spec, engine.models().len(), 3, Duration::from_millis(250));
    assert_eq!(schedule.stalled_conns, 2, "scenario wedges two connections");

    // wedge the slow-loris connections first, then run a well-behaved
    // replay through a sibling connection while they hold their sockets
    let stalled = stall_connections(&server.addr, schedule.stalled_conns).expect("stall");
    assert_eq!(stalled.len(), schedule.stalled_conns as usize);
    let cfg = ReplayConfig {
        pacing: Pacing::Wall { speedup: 4.0 },
        slo_p99_us: 1_000_000,
        ..ReplayConfig::default()
    };
    let report = replay_endpoint(&server.addr, &schedule, &cfg).expect("sibling replay");
    assert_eq!(report.submitted, schedule.arrivals.len() as u64);
    assert_eq!(
        report.submitted,
        report.served + report.shed + report.rejected + report.errors,
        "accounting identity: {report}"
    );
    assert_eq!(report.errors, 0, "stalled connections must not cost replies: {report}");
    assert_eq!(report.served, report.submitted, "every sibling request answered: {report}");

    drop(stalled); // release the wedged reader threads only after the replay
    server.stop();
    drop(engine);
    handle.shutdown();
}
