//! Integration tests: partition engine x scheduler x experiments across
//! the three full networks (no artifacts needed — pure cost models).

use hetero_dnn::experiments;
use hetero_dnn::graph::{models, ModuleKind};
use hetero_dnn::partition::{Planner, Strategy};
use hetero_dnn::sched::{self, IdleParams};

fn planner() -> Planner {
    Planner::default()
}

#[test]
fn every_model_validates_at_all_fig4_resolutions() {
    for &res in &experiments::FIG4_RESOLUTIONS {
        models::squeezenet(res).validate().unwrap();
        models::mobilenetv2_05(res).validate().unwrap();
        models::shufflenetv2_05(res).validate().unwrap();
    }
}

#[test]
fn paper_plan_beats_gpu_only_on_every_model() {
    // the paper's headline claim, end to end
    let p = planner();
    for g in models::all_models() {
        let base = sched::evaluate_model_with(
            &p.plan_model(&g, Strategy::GpuOnly),
            IdleParams::paper(),
        );
        let het = sched::evaluate_model_with(&p.plan_model_paper(&g), IdleParams::paper());
        assert!(
            het.total.joules < base.total.joules,
            "{}: energy {} !< {}",
            g.name,
            het.total.joules,
            base.total.joules
        );
        assert!(
            het.total.seconds <= base.total.seconds * 1.02,
            "{}: latency regressed {} vs {}",
            g.name,
            het.total.seconds,
            base.total.seconds
        );
    }
}

#[test]
fn paper_headline_bands() {
    // abstract: MNv2 12-30% E, SqueezeNet 21-28% E, SNv2 ~21-25%.
    // We accept the reproduced band when the direction and rough magnitude
    // hold (5-35% energy reduction per net, latency never regresses).
    let p = planner();
    for g in models::all_models() {
        let base = sched::evaluate_model_with(
            &p.plan_model(&g, Strategy::GpuOnly),
            IdleParams::paper(),
        )
        .total;
        let het = sched::evaluate_model_with(&p.plan_model_paper(&g), IdleParams::paper()).total;
        let red = (1.0 - het.joules / base.joules) * 100.0;
        assert!((5.0..40.0).contains(&red), "{}: energy reduction {red}% out of band", g.name);
    }
}

#[test]
fn strict_idle_billing_reduces_but_keeps_order() {
    // ablation: honest whole-run board power cuts the gain; hetero should
    // still not be dramatically worse than GPU-only
    let p = planner();
    for g in models::all_models() {
        let base =
            sched::evaluate_model_strict(&p.plan_model(&g, Strategy::GpuOnly), IdleParams::default());
        let het = sched::evaluate_model_strict(&p.plan_model_paper(&g), IdleParams::default());
        let paper_gain = {
            let b = sched::evaluate_model_with(&p.plan_model(&g, Strategy::GpuOnly), IdleParams::paper());
            let h = sched::evaluate_model_with(&p.plan_model_paper(&g), IdleParams::paper());
            b.total.joules / h.total.joules
        };
        let strict_gain = base.total.joules / het.total.joules;
        assert!(
            strict_gain < paper_gain,
            "{}: strict billing should shrink the gain ({strict_gain} !< {paper_gain})",
            g.name
        );
        assert!(strict_gain > 0.85, "{}: hetero collapses under strict billing", g.name);
    }
}

#[test]
fn shared_fabric_plan_is_deployable() {
    // deployment planner respects the resident-set budget AND still wins
    let p = planner();
    let dev = p.sdhm().dev;
    let ceiling = (dev.alms as f64 * dev.util_ceiling) as u64;
    for g in models::all_models() {
        let plan = p.plan_model(&g, Strategy::Auto);
        assert!(plan.fpga_usage().alms <= ceiling, "{}", g.name);
        let base = sched::evaluate_model(&p.plan_model(&g, Strategy::GpuOnly));
        let auto = sched::evaluate_model(&plan);
        assert!(auto.total.joules <= base.total.joules * 1.001, "{}", g.name);
    }
}

#[test]
fn fig4_resolution_trend() {
    // paper §V-B: the gain increases with IFM size (MobileNetV2)
    let p = planner();
    let gain_at = |res: usize| {
        let pts = experiments::fig4_points(&p, "mobilenetv2_05", res);
        let gpu: f64 = pts.iter().map(|x| x.gpu.joules).sum();
        let het: f64 = pts.iter().map(|x| x.hetero.joules).sum();
        gpu / het
    };
    let hi = gain_at(224);
    let lo = gain_at(96);
    assert!(hi >= lo * 0.95, "gain should not collapse at high res: {hi} vs {lo}");
}

#[test]
fn pool_and_plain_modules_never_partitioned() {
    let p = planner();
    for g in models::all_models() {
        let plan = p.plan_model_paper(&g);
        for (m, mp) in g.modules.iter().zip(&plan.modules) {
            if matches!(m.kind, ModuleKind::Plain | ModuleKind::Pool) {
                assert!(!mp.uses_fpga, "{} {} on FPGA", g.name, m.name);
            }
        }
    }
}

#[test]
fn table1_ordering_matches_paper() {
    // paper Table I: Bottleneck has the largest energy gain among the three
    // published rows; ours must at least keep every family >= 1.0x and the
    // Stage/Bottleneck families clearly above 1.05x
    let gains = experiments::table1_gains(&planner());
    for (label, gain) in &gains {
        assert!(gain.energy_gain >= 1.0, "{label}: {}", gain.energy_gain);
        assert!(gain.latency_speedup >= 0.98, "{label}: {}", gain.latency_speedup);
    }
}

#[test]
fn table1_coverage_reflects_resource_cliff() {
    // some instances of each family must be partitioned; MNv2's late, wide
    // bottlenecks must NOT all fit (the paper's §III-A resource cliff)
    let cov = experiments::table1_coverage(&planner());
    for (label, c) in &cov {
        assert!(*c > 0.0, "{label}: nothing partitioned");
    }
    let mnv2 = cov.iter().find(|(l, _)| l.contains("Bottleneck")).unwrap().1;
    assert!(mnv2 < 1.0, "every bottleneck fit the FPGA — cliff missing ({mnv2})");
}

#[test]
fn fig1_report_generates() {
    let r = experiments::fig1(&planner());
    assert_eq!(r.rows.len(), 18);
    let csv = r.to_csv();
    assert!(csv.lines().count() == 19);
}

#[test]
fn fig4_reports_generate_for_all_models() {
    let p = planner();
    for m in ["squeezenet", "mobilenetv2_05", "shufflenetv2_05"] {
        let r = experiments::fig4(&p, m);
        assert!(r.rows.len() > 20, "{m}: {}", r.rows.len());
    }
}
