//! Online heterogeneous executor: end-to-end properties.
//!
//! Two claims anchor the subsystem (ISSUE/DESIGN.md §10):
//!
//! 1. **Bit-identity** — splitting a model across simulated devices at
//!    its plan's boundary changes *nothing* numerically: staged and
//!    pipelined execution equal the monolithic `run_batch` path exactly,
//!    for all three paper nets.
//! 2. **Throughput fidelity** — the pipelined lanes reproduce the
//!    analytic steady state: measured period ≈ bottleneck service time ×
//!    time scale, bottleneck device as predicted by
//!    `sched::pipeline::service_demand`, and the hybrid placement
//!    out-serves the GPU-only placement wall-clock.

use hetero_dnn::coordinator::{Completion, EngineBuilder, InferenceRequest, ModelSpec, Placement};
use hetero_dnn::graph::models;
use hetero_dnn::hetero::{HeteroExecutable, HeteroPipeline, PipelineConfig};
use hetero_dnn::partition::{Planner, Resource, Strategy};
use hetero_dnn::runtime::{Runtime, Tensor};
use hetero_dnn::sched::pipeline::service_demand;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

const NETS: [&str; 3] = ["squeezenet", "mobilenetv2_05", "shufflenetv2_05"];

/// Tests whose lanes busy-spin simulated device time (or that assert on
/// wall-clock) take this lock: run concurrently on a small CI runner
/// they would deschedule each other's lanes and inflate measured
/// periods past tolerance.
static SPIN: Mutex<()> = Mutex::new(());

fn spin_guard() -> std::sync::MutexGuard<'static, ()> {
    SPIN.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

fn graph_of(name: &str) -> hetero_dnn::graph::ModelGraph {
    models::by_name(name, 224).expect("one of the three paper nets")
}

#[test]
fn staged_split_bit_identical_to_monolithic_run_batch_all_nets() {
    // the acceptance criterion: HeteroExecutable == monolithic executor,
    // bit for bit, for squeezenet, mobilenetv2 and shufflenetv2
    let rt = Runtime::simulated();
    let planner = Planner::default();
    for net in NETS {
        let artifact = format!("{net}_224");
        let exe = rt.load(&artifact).expect("load net artifact");
        let plan = planner.plan_model(&graph_of(net), Strategy::Paper);
        let hexe = HeteroExecutable::from_plan(&plan, exe.entry.inputs.len());
        assert_eq!(hexe.stages().len(), 3, "{net}: expected fpga/link/gpu lanes");

        let base_inputs = rt.synth_inputs(&artifact, 7).expect("synth");
        // 5 requests with distinct images, shared weights — exactly what
        // a served batch looks like
        let per_req: Vec<Vec<Tensor>> = (0..5u64)
            .map(|s| {
                let mut inputs = base_inputs.clone();
                inputs[0] = Tensor::randn(&inputs[0].shape, 1000 + s);
                inputs
            })
            .collect();
        let refs: Vec<&[Tensor]> = per_req.iter().map(Vec::as_slice).collect();
        let monolithic = exe.run_batch(&refs).expect("monolithic run_batch");

        for (inputs, mono) in per_req.iter().zip(&monolithic) {
            let lits = exe.prepare(inputs, 0).expect("prepare");
            let lit_refs: Vec<&hetero_dnn::runtime::Literal> = lits.iter().collect();
            let staged = hexe.run(&exe, &lit_refs).expect("staged run");
            assert_eq!(staged.len(), mono.len(), "{net}");
            for (a, b) in staged.iter().zip(mono) {
                assert_eq!(a, b, "{net}: staged output != monolithic output");
            }
        }
    }
}

#[test]
fn pipeline_outputs_bit_identical_and_in_order() {
    // the threaded lanes deliver the same bits as the sync staged path,
    // in submission order (every lane is FIFO)
    let rt = Runtime::simulated();
    let planner = Planner::default();
    let net = "squeezenet";
    let artifact = format!("{net}_224");
    let exe = rt.load(&artifact).unwrap();
    let plan = planner.plan_model(&graph_of(net), Strategy::Paper);
    let hexe = HeteroExecutable::from_plan(&plan, exe.entry.inputs.len());

    let done: Arc<Mutex<Vec<(usize, Vec<Tensor>)>>> = Arc::new(Mutex::new(Vec::new()));
    let sink = done.clone();
    let pipe: HeteroPipeline<usize> = HeteroPipeline::start(
        &artifact,
        0,
        &hexe,
        // no simulated time: this test is about bits and ordering
        PipelineConfig { queue_depth: 2, time_scale: 0.0 },
        Arc::new(move |idx, result| {
            sink.lock().unwrap().push((idx, result.expect("pipeline job").outputs));
        }),
    )
    .expect("pipeline");

    let n = 8usize;
    let base_inputs = rt.synth_inputs(&artifact, 0).unwrap();
    let images: Vec<Tensor> = (0..n as u64)
        .map(|s| Tensor::randn(&base_inputs[0].shape, 2000 + s))
        .collect();
    for (i, x) in images.iter().enumerate() {
        pipe.submit(i, x.clone()).expect("submit");
    }
    pipe.shutdown(); // drains every lane, so all completions landed

    let done = done.lock().unwrap();
    assert_eq!(done.len(), n);
    for (pos, (idx, outs)) in done.iter().enumerate() {
        assert_eq!(*idx, pos, "completions must arrive in submission order");
        let mut inputs = base_inputs.clone();
        inputs[0] = images[*idx].clone();
        let expected = exe.run(&inputs).unwrap();
        assert_eq!(outs.len(), expected.len());
        for (a, b) in outs.iter().zip(&expected) {
            assert_eq!(a, b, "pipelined output != monolithic output");
        }
    }
}

#[test]
fn pipeline_rejects_bad_input_shape() {
    let rt = Runtime::simulated();
    let planner = Planner::default();
    let artifact = "squeezenet_224";
    let exe = rt.load(artifact).unwrap();
    let plan = planner.plan_model(&graph_of("squeezenet"), Strategy::Paper);
    let hexe = HeteroExecutable::from_plan(&plan, exe.entry.inputs.len());
    let pipe: HeteroPipeline<usize> = HeteroPipeline::start(
        artifact,
        0,
        &hexe,
        PipelineConfig { queue_depth: 1, time_scale: 0.0 },
        Arc::new(|_, _| {}),
    )
    .expect("pipeline");
    let err = pipe.submit(0, Tensor::zeros(&[1, 2, 3])).expect_err("bad shape must fail");
    assert!(err.to_string().contains("shape"), "{err}");
    pipe.shutdown();
}

#[test]
fn measured_steady_state_matches_service_demand_prediction() {
    let _spin = spin_guard();
    // the property test: wall-clock period and bottleneck device of the
    // running pipeline agree with sched::pipeline's analytic reduction
    let rt = Runtime::simulated();
    let planner = Planner::default();
    let time_scale = 0.1;
    let n = 32usize;
    for (net, strat) in [("squeezenet", Strategy::Paper), ("squeezenet", Strategy::GpuOnly)] {
        let artifact = format!("{net}_224");
        let exe = rt.load(&artifact).unwrap();
        let plan = planner.plan_model(&graph_of(net), strat);
        let demand = service_demand(&plan);
        let (predicted_resource, predicted_period) = demand.bottleneck();
        let hexe = HeteroExecutable::from_plan(&plan, exe.entry.inputs.len());

        let stamps: Arc<Mutex<Vec<Instant>>> = Arc::new(Mutex::new(Vec::new()));
        let sink = stamps.clone();
        let pipe: HeteroPipeline<usize> = HeteroPipeline::start(
            &artifact,
            0,
            &hexe,
            PipelineConfig { queue_depth: 2, time_scale },
            Arc::new(move |_, result| {
                result.expect("pipeline job");
                sink.lock().unwrap().push(Instant::now());
            }),
        )
        .expect("pipeline");
        // pre-generate the images: synthesizing a 224x224x3 tensor costs
        // milliseconds, which would starve the pipeline and measure the
        // generator instead of the bottleneck lane
        let shape = exe.entry.inputs[0].shape.clone();
        let images: Vec<Tensor> = (0..n as u64).map(|s| Tensor::randn(&shape, s)).collect();
        for (i, x) in images.into_iter().enumerate() {
            pipe.submit(i, x).expect("submit");
        }
        let metrics = pipe.metrics.clone();
        pipe.shutdown();

        let stamps = stamps.lock().unwrap();
        assert_eq!(stamps.len(), n);
        // steady-state period: skip the fill, average the rest
        let warm = 4usize;
        let measured = (stamps[n - 1] - stamps[warm]).as_secs_f64() / (n - 1 - warm) as f64;
        let predicted = predicted_period * time_scale;
        let rel = (measured - predicted).abs() / predicted;
        assert!(
            rel < 0.35,
            "{net} {strat}: measured period {measured:.6}s vs predicted {predicted:.6}s \
             (rel err {rel:.2})"
        );
        // the measured bottleneck lane is the predicted one
        let expected_lane = match predicted_resource {
            Resource::Gpu => "gpu",
            Resource::Fpga => "fpga",
            Resource::Link => "link",
        };
        assert_eq!(metrics.busiest().0, expected_lane, "{net} {strat}");
        assert_eq!(metrics.images(), n as u64);
        if strat == Strategy::Paper {
            assert!(metrics.transferred_elems() > 0, "hybrid must cross the link");
            assert!(metrics.fpga.jobs() == n as u64, "every image visits the FPGA lane");
        }
    }
}

/// Drive `n` pipelined requests through an engine and return the wall time.
fn drive(engine: &hetero_dnn::coordinator::Engine, model: &str, n: usize) -> Duration {
    let shape = engine.input_shape(model).expect("registered");
    let xs: Vec<Tensor> = (0..n as u64).map(|s| Tensor::randn(&shape, s)).collect();
    engine.infer(InferenceRequest::new(model.to_string(), xs[0].clone())).expect("warm");
    let (sink, done) = mpsc::channel::<Completion>();
    let t0 = Instant::now();
    let (mut submitted, mut received, mut in_flight) = (0usize, 0usize, 0usize);
    while received < n {
        while submitted < n && in_flight < 6 {
            let req = InferenceRequest::new(model.to_string(), xs[submitted].clone());
            engine.submit(req, submitted as u64, &sink).expect("submit");
            submitted += 1;
            in_flight += 1;
        }
        done.recv().expect("completion").result.expect("infer ok");
        received += 1;
        in_flight -= 1;
    }
    t0.elapsed()
}

#[test]
fn engine_hetero_placement_serves_bit_identical_to_pool() {
    let _spin = spin_guard();
    // same model, same seed, two placements: responses must be identical
    let pool = EngineBuilder::new()
        .max_wait(Duration::ZERO)
        .model(ModelSpec::net("squeezenet").workers(2))
        .build()
        .expect("pool engine");
    let het = EngineBuilder::new()
        .max_wait(Duration::ZERO)
        .model(ModelSpec::net("squeezenet").placement(Strategy::Paper))
        .build()
        .expect("hetero engine");
    assert_eq!(pool.engine.placement("squeezenet"), Some(Placement::Pool));
    assert_eq!(het.engine.placement("squeezenet"), Some(Placement::Hetero));
    assert!(pool.engine.device_metrics("squeezenet").is_none());

    let shape = pool.engine.input_shape("squeezenet").expect("registered");
    for s in 0..4u64 {
        let x = Tensor::randn(&shape, 40 + s);
        let a = pool
            .engine
            .infer(InferenceRequest::new("squeezenet", x.clone()))
            .expect("pool infer");
        let b = het
            .engine
            .infer(InferenceRequest::new("squeezenet", x))
            .expect("hetero infer");
        assert_eq!(a.output, b.output, "placement changed the bits");
        assert!(!b.cached);
        assert_eq!(b.batch_size, 1, "the pipeline services images one at a time");
    }

    // device counters observed the traffic
    let dm = het.engine.device_metrics("squeezenet").expect("hetero metrics");
    assert_eq!(dm.images(), 4);
    assert!(dm.gpu.sim_busy() > Duration::ZERO);
    assert!(dm.fpga.sim_busy() > Duration::ZERO);
    assert!(dm.transferred_bytes() > 0);

    // the serving metrics carry over: served counts, latency histogram
    let m = het.engine.metrics("squeezenet").expect("metrics");
    let m = m.lock().unwrap();
    assert_eq!(m.served, 4);
    assert!(m.percentile(0.5) > 0);
    drop(m);

    pool.shutdown();
    het.shutdown();
}

#[test]
fn engine_hetero_cache_hits_bypass_the_lanes() {
    let _spin = spin_guard();
    let handle = EngineBuilder::new()
        .max_wait(Duration::ZERO)
        .model(ModelSpec::net("squeezenet").placement(Strategy::Paper).cache(16))
        .build()
        .expect("engine");
    let engine = handle.engine.clone();
    let x = Tensor::randn(&engine.input_shape("squeezenet").unwrap(), 9);
    let miss = engine.infer(InferenceRequest::new("squeezenet", x.clone())).expect("miss");
    assert!(!miss.cached);
    let images_after_miss = engine.device_metrics("squeezenet").unwrap().images();
    let hit = engine.infer(InferenceRequest::new("squeezenet", x)).expect("hit");
    assert!(hit.cached);
    assert_eq!(hit.output, miss.output, "cache hit must be bit-identical");
    // the hit never entered the pipeline
    assert_eq!(engine.device_metrics("squeezenet").unwrap().images(), images_after_miss);
    handle.shutdown();
}

#[test]
fn hybrid_placement_outserves_gpu_only_placement() {
    let _spin = spin_guard();
    // the serving-layer version of the paper's headline. Both engines pay
    // simulated device time; the hybrid pipeline must realize a
    // meaningful share of the analytically predicted speedup.
    let planner = Planner::default();
    let g = graph_of("squeezenet");
    let base = service_demand(&planner.plan_model(&g, Strategy::GpuOnly));
    let het = service_demand(&planner.plan_model(&g, Strategy::Paper));
    let predicted = base.bottleneck().1 / het.bottleneck().1;
    assert!(predicted > 1.0, "plan must predict a hybrid win ({predicted})");

    let n = 24usize;
    let mut walls = Vec::new();
    for strat in [Strategy::GpuOnly, Strategy::Paper] {
        let handle = EngineBuilder::new()
            .max_wait(Duration::ZERO)
            .model(ModelSpec::net("squeezenet").placement(strat))
            .build()
            .expect("engine");
        walls.push(drive(&handle.engine, "squeezenet", n));
        handle.shutdown();
    }
    let measured = walls[0].as_secs_f64() / walls[1].as_secs_f64();
    let floor = 1.0 + 0.3 * (predicted - 1.0);
    assert!(
        measured > floor,
        "hybrid realized {measured:.2}x vs gpu-only (predicted {predicted:.2}x, floor {floor:.2}x)"
    );
}

#[test]
fn hetero_model_hot_swaps_cleanly() {
    let _spin = spin_guard();
    // register a hetero-placed model on a live engine, serve, retire it —
    // siblings undisturbed, drain answered
    let handle = EngineBuilder::new()
        .max_wait(Duration::ZERO)
        .model(ModelSpec::new("fire", "fire_full", "squeezenet").workers(1))
        .build()
        .expect("engine");
    let engine = handle.engine.clone();
    engine
        .register(ModelSpec::net("shufflenetv2_05").placement(Strategy::Paper))
        .expect("register hetero model");
    assert_eq!(engine.placement("shufflenetv2_05"), Some(Placement::Hetero));

    let x = Tensor::randn(&engine.input_shape("shufflenetv2_05").unwrap(), 3);
    let resp = engine
        .infer(InferenceRequest::new("shufflenetv2_05", x))
        .expect("hetero infer on hot-swapped model");
    assert_eq!(resp.model, "shufflenetv2_05");

    engine.retire("shufflenetv2_05").expect("retire");
    assert_eq!(engine.models(), vec!["fire"]);
    // the sibling pool still serves
    let y = Tensor::randn(&engine.input_shape("fire").unwrap(), 4);
    engine.infer(InferenceRequest::new("fire", y)).expect("sibling survives");
    drop(engine);
    handle.shutdown();
}
