//! Node-level device arbitration: the contention battery (DESIGN.md §14).
//!
//! Three claims pin the shared-device runtime:
//!
//! 1. **Bit-identity** — arbitrating the simulated GPU/FPGA/link behind a
//!    node-scoped [`DeviceSet`] changes *nothing* numerically: a shared
//!    engine answers every request with exactly the bits a private-device
//!    engine produces, for all three paper nets.
//! 2. **No starvation** — two co-located hetero tenants both complete
//!    their whole offered load, and the victim's p99 stays within a
//!    generous bound of its solo-tenant run.
//! 3. **Exact accounting** — the node's per-device grant/hold counters
//!    reconcile exactly with the sum of the tenants' own lane counters
//!    (the identity [`ArbiterCounters`] documents).
//!
//! [`DeviceSet`]: hetero_dnn::runtime::arbiter::DeviceSet
//! [`ArbiterCounters`]: hetero_dnn::metrics::device::ArbiterCounters

use hetero_dnn::coordinator::{
    Completion, Engine, EngineBuilder, EngineHandle, InferenceRequest, ModelSpec,
};
use hetero_dnn::metrics::device::{DeviceCounters, HeteroMetrics};
use hetero_dnn::partition::Strategy;
use hetero_dnn::runtime::Tensor;
use std::sync::mpsc;
use std::sync::Mutex;
use std::time::Duration;

const NETS: [&str; 3] = ["squeezenet", "mobilenetv2_05", "shufflenetv2_05"];

/// Same discipline as integration_hetero.rs: lanes busy-spin simulated
/// device time, so tests that measure or contend serialize against each
/// other rather than descheduling each other's lanes on a small runner.
static SPIN: Mutex<()> = Mutex::new(());

fn spin_guard() -> std::sync::MutexGuard<'static, ()> {
    SPIN.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// A shared-node engine: every listed net placed on the hetero pipeline,
/// all tenants arbitrating one [`hetero_dnn::runtime::arbiter::DeviceSet`].
fn shared_engine(nets: &[&str]) -> EngineHandle {
    let mut b = EngineBuilder::new().shared_devices().max_wait(Duration::ZERO);
    for net in nets {
        b = b.model(ModelSpec::net(net).placement(Strategy::Paper));
    }
    b.build().expect("shared-device engine")
}

/// Drive `n` pipelined requests through one model, keeping a small
/// submission window open (the hetero battery's driver shape).
fn drive(engine: &Engine, model: &str, n: usize) {
    let shape = engine.input_shape(model).expect("registered");
    let xs: Vec<Tensor> = (0..n as u64).map(|s| Tensor::randn(&shape, s)).collect();
    engine.infer(InferenceRequest::new(model.to_string(), xs[0].clone())).expect("warm");
    let (sink, done) = mpsc::channel::<Completion>();
    let (mut submitted, mut received, mut in_flight) = (0usize, 0usize, 0usize);
    while received < n {
        while submitted < n && in_flight < 6 {
            let req = InferenceRequest::new(model.to_string(), xs[submitted].clone());
            engine.submit(req, submitted as u64, &sink).expect("submit");
            submitted += 1;
            in_flight += 1;
        }
        done.recv().expect("completion").result.expect("infer ok");
        received += 1;
        in_flight -= 1;
    }
}

fn p99_us(engine: &Engine, model: &str) -> u64 {
    let m = engine.metrics(model).expect("registered");
    let p99 = m.lock().unwrap().percentile(0.99);
    p99
}

#[test]
fn shared_device_execution_bit_identical_to_private_all_nets() {
    let _spin = spin_guard();
    // the acceptance criterion: acquiring devices through the arbiter
    // instead of owning them must not change a single output bit
    for net in NETS {
        let private = EngineBuilder::new()
            .max_wait(Duration::ZERO)
            .model(ModelSpec::net(net).placement(Strategy::Paper))
            .build()
            .expect("private engine");
        let shared = shared_engine(&[net]);
        assert!(private.engine.node_device_metrics().is_none());
        let node = shared.engine.node_device_metrics().expect("shared node metrics");

        let shape = private.engine.input_shape(net).expect("registered");
        for s in 0..4u64 {
            let x = Tensor::randn(&shape, 90 + s);
            let a = private.engine.infer(InferenceRequest::new(net, x.clone())).expect("private");
            let b = shared.engine.infer(InferenceRequest::new(net, x)).expect("shared");
            assert_eq!(a.output, b.output, "{net}: arbitration changed the bits");
            assert!(!b.cached);
        }
        // every lane really went through the grant queue
        assert!(node.gpu.grants() > 0, "{net}: gpu never granted");
        assert!(node.fpga.grants() > 0, "{net}: fpga never granted");
        assert!(node.link.grants() > 0, "{net}: link never granted");
        private.shutdown();
        shared.shutdown();
    }
}

#[test]
fn colocated_tenants_both_progress_with_bounded_p99_inflation() {
    let _spin = spin_guard();
    let n = 24usize;

    // solo baseline: one tenant alone on the shared node
    let solo = shared_engine(&["squeezenet"]);
    drive(&solo.engine, "squeezenet", n);
    let solo_p99 = p99_us(&solo.engine, "squeezenet");
    assert!(solo_p99 > 0, "solo run must land a latency histogram");
    solo.shutdown();

    // co-located: two hetero tenants arbitrating the same three devices
    let both = shared_engine(&["squeezenet", "shufflenetv2_05"]);
    let engine = both.engine.clone();
    std::thread::scope(|s| {
        let a = s.spawn(|| drive(&engine, "squeezenet", n));
        let b = s.spawn(|| drive(&engine, "shufflenetv2_05", n));
        a.join().expect("squeezenet tenant");
        b.join().expect("shufflenetv2 tenant");
    });

    // no starvation: both tenants completed their whole offered load
    // (the warm-up request plus the n windowed ones), error-free
    for model in ["squeezenet", "shufflenetv2_05"] {
        let m = engine.metrics(model).expect("registered");
        let m = m.lock().unwrap();
        assert_eq!(m.served, (n + 1) as u64, "{model}: every request answered");
        assert_eq!(m.errors, 0, "{model}: no errors under contention");
    }

    // bounded inflation: a generous factor plus absolute slack, so the
    // assertion survives noisy CI runners while still catching a tenant
    // that queues unboundedly behind its neighbour
    let co_p99 = p99_us(&engine, "squeezenet");
    let bound = solo_p99.saturating_mul(25).saturating_add(100_000);
    assert!(co_p99 <= bound, "co-located p99 {co_p99}us vs solo {solo_p99}us (bound {bound}us)");

    // the node observed both tenants, and a clean run cancels nothing
    let node = engine.node_device_metrics().expect("node metrics");
    assert!(node.gpu.grants() >= 2 * n as u64, "gpu grants: {}", node.gpu.grants());
    assert_eq!(node.gpu.cancelled() + node.fpga.cancelled() + node.link.cancelled(), 0);
    drop(engine);
    both.shutdown();
}

#[test]
fn node_counters_reconcile_exactly_with_tenant_lane_counters() {
    let _spin = spin_guard();
    let handle = shared_engine(&["squeezenet", "shufflenetv2_05"]);
    let engine = handle.engine.clone();
    for model in ["squeezenet", "shufflenetv2_05"] {
        drive(&engine, model, 8);
    }
    let node = engine.node_device_metrics().expect("node metrics");
    let tenants: Vec<_> = ["squeezenet", "shufflenetv2_05"]
        .iter()
        .map(|m| engine.device_metrics(m).expect("hetero tenant"))
        .collect();
    drop(engine);
    // drain and join every lane first: all counters are final after this
    handle.shutdown();

    type Pick = fn(&HeteroMetrics) -> &DeviceCounters;
    let checks: [(&str, &hetero_dnn::metrics::device::ArbiterCounters, Pick); 3] = [
        ("gpu", &node.gpu, |t| &t.gpu),
        ("fpga", &node.fpga, |t| &t.fpga),
        ("link", &node.link, |t| &t.link),
    ];
    for (name, arb, pick) in checks {
        let jobs: u64 = tenants.iter().map(|t| pick(t).jobs()).sum();
        let wall_us: u64 = tenants.iter().map(|t| pick(t).wall_busy().as_micros() as u64).sum();
        assert!(jobs > 0, "{name}: tenants recorded no jobs");
        // the accounting identity is exact, not approximate: both sides
        // accumulate the same per-grant Duration under the same
        // microsecond truncation
        assert_eq!(arb.grants(), jobs, "{name}: node grants vs Σ tenant jobs");
        assert_eq!(
            arb.holds().as_micros() as u64,
            wall_us,
            "{name}: node holds vs Σ tenant wall busy"
        );
        assert_eq!(arb.cancelled(), 0, "{name}: nothing cancelled in a clean run");
    }
    let (name, held) = node.most_contended();
    assert!(["gpu", "fpga", "link"].contains(&name));
    assert!(held > Duration::ZERO, "some device must have been held");
}
