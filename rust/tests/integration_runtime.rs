//! Integration tests: PJRT runtime x AOT artifacts.
//!
//! These are the *functional ground truth* tests of the three-layer stack:
//! Rust loads the HLO text that python/compile/aot.py lowered from the L2
//! JAX modules (which call the L1 Pallas kernels), executes it on the PJRT
//! CPU client, and checks the paper's partition algebra numerically:
//! splitting a module across devices must not change its output.
//!
//! Requires `make artifacts` (skipped otherwise).

use hetero_dnn::config::Manifest;
use hetero_dnn::runtime::{Runtime, Tensor};

fn runtime_or_skip() -> Option<Runtime> {
    if Manifest::load().is_err() {
        eprintln!("artifacts not built; skipping runtime integration tests");
        return None;
    }
    let rt = Runtime::new().expect("runtime");
    if !rt.has_real_backend() {
        // the numeric assertions below (partition algebra, q8 drift) are
        // statements about the real lowered kernels; the deterministic
        // stand-in backend cannot satisfy them (DESIGN.md §Backends)
        eprintln!("no real (PJRT) backend in this build; skipping numeric artifact tests");
        return None;
    }
    Some(rt)
}

#[test]
fn platform_is_cpu_pjrt() {
    let Some(rt) = runtime_or_skip() else { return };
    assert!(rt.platform().to_lowercase().contains("cpu"), "{}", rt.platform());
}

#[test]
fn manifest_has_all_families() {
    let Some(rt) = runtime_or_skip() else { return };
    for tag in ["op", "module", "net", "fpga-part", "gpu-part", "q8"] {
        assert!(!rt.manifest.tagged(tag).is_empty(), "no artifacts tagged {tag}");
    }
}

#[test]
fn conv3x3_runs_and_is_finite() {
    let Some(rt) = runtime_or_skip() else { return };
    let exe = rt.load("conv3x3").expect("load");
    let inputs = rt.synth_inputs("conv3x3", 1).unwrap();
    let outs = exe.run(&inputs).expect("run");
    assert_eq!(outs.len(), 1);
    assert_eq!(outs[0].shape, vec![1, 56, 56, 32]);
    assert!(outs[0].data.iter().all(|v| v.is_finite()));
    assert!(outs[0].data.iter().any(|&v| v != 0.0));
}

#[test]
fn executable_cache_returns_same_instance() {
    let Some(rt) = runtime_or_skip() else { return };
    let a = rt.load("pwconv_relu").unwrap();
    let b = rt.load("pwconv_relu").unwrap();
    assert!(std::rc::Rc::ptr_eq(&a, &b));
}

#[test]
fn wrong_arity_rejected() {
    let Some(rt) = runtime_or_skip() else { return };
    let exe = rt.load("conv3x3").unwrap();
    let inputs = rt.synth_inputs("conv3x3", 1).unwrap();
    assert!(exe.run(&inputs[..1]).is_err());
}

#[test]
fn wrong_shape_rejected() {
    let Some(rt) = runtime_or_skip() else { return };
    let exe = rt.load("conv3x3").unwrap();
    let mut inputs = rt.synth_inputs("conv3x3", 1).unwrap();
    inputs[0] = Tensor::zeros(&[1, 28, 28, 16]);
    assert!(exe.run(&inputs).is_err());
}

#[test]
fn execution_is_deterministic() {
    let Some(rt) = runtime_or_skip() else { return };
    let exe = rt.load("fire_full").unwrap();
    let inputs = rt.synth_inputs("fire_full", 7).unwrap();
    let a = exe.run(&inputs).unwrap();
    let b = exe.run(&inputs).unwrap();
    assert_eq!(a[0].max_abs_diff(&b[0]), 0.0);
}

// ---------------------------------------------------------------------------
// Partition algebra: the Fig 2 equivalences, end to end through PJRT.

#[test]
fn fire_split_equals_monolith_fig2b() {
    // concat(expand1(GPU), expand3(FPGA, f32 twin)) == fire_full
    let Some(rt) = runtime_or_skip() else { return };
    let full = rt.load("fire_full").unwrap();
    let gpu = rt.load("fire_gpu").unwrap();
    let fpga = rt.load("fire_fpga_f32").unwrap();

    let inputs = rt.synth_inputs("fire_full", 42).unwrap(); // x, ws, we1, we3
    let want = &full.run(&inputs).unwrap()[0];

    let gpu_out = gpu.run(&inputs[..3]).unwrap(); // (s, a)
    let (s, a) = (&gpu_out[0], &gpu_out[1]);
    let b = &fpga.run(&[s.clone(), inputs[3].clone()]).unwrap()[0];

    let got = a.concat_last(b);
    let err = got.max_abs_diff(want);
    assert!(err < 1e-4, "fire split mismatch {err}");
}

#[test]
fn fire_fpga_q8_tracks_float_dhm_datapath() {
    // the 8-bit DHM path deviates from float by quantization noise only
    let Some(rt) = runtime_or_skip() else { return };
    let gpu = rt.load("fire_gpu").unwrap();
    let q8 = rt.load("fire_fpga").unwrap();
    let f32t = rt.load("fire_fpga_f32").unwrap();

    let inputs = rt.synth_inputs("fire_full", 43).unwrap();
    let s = gpu.run(&inputs[..3]).unwrap().remove(0);
    let yq = &q8.run(&[s.clone(), inputs[3].clone()]).unwrap()[0];
    let yf = &f32t.run(&[s, inputs[3].clone()]).unwrap()[0];
    let rel = yq.rel_error(yf);
    assert!(rel < 0.05, "q8 deviates {rel} from float");
    assert!(rel > 0.0, "q8 output suspiciously identical to float");
}

#[test]
fn bottleneck_split_equals_monolith_fig2a() {
    // project(FPGA f32 twin)(gpu_part(x)) + x == bottleneck_full (residual)
    let Some(rt) = runtime_or_skip() else { return };
    let full = rt.load("bottleneck_full").unwrap();
    let gpu = rt.load("bottleneck_gpu").unwrap();
    let fpga = rt.load("bottleneck_fpga_f32").unwrap();

    let inputs = rt.synth_inputs("bottleneck_full", 11).unwrap(); // x, we, wd, wp
    let want = &full.run(&inputs).unwrap()[0];

    let t = gpu.run(&inputs[..3]).unwrap().remove(0);
    let y = &fpga.run(&[t, inputs[3].clone()]).unwrap()[0];
    // residual add happens GPU-side after the FPGA returns
    let got = Tensor::new(
        y.shape.clone(),
        y.data.iter().zip(&inputs[0].data).map(|(a, b)| a + b).collect(),
    );
    let err = got.max_abs_diff(want);
    assert!(err < 1e-4, "bottleneck split mismatch {err}");
}

#[test]
fn shuffle_basic_split_equals_monolith_fig2c() {
    // concat(left, fused_right_branch(FPGA)) + shuffle == shuffle_basic_full
    let Some(rt) = runtime_or_skip() else { return };
    let full = rt.load("shuffle_basic_full").unwrap();
    let fpga = rt.load("shuffle_basic_fpga").unwrap();

    let inputs = rt.synth_inputs("shuffle_basic_full", 19).unwrap(); // x, w1, wd, w2
    let want = &full.run(&inputs).unwrap()[0];

    let c = inputs[0].shape[3];
    let left = inputs[0].slice_last(0, c / 2);
    let right = inputs[0].slice_last(c / 2, c);
    let r = &fpga
        .run(&[right, inputs[1].clone(), inputs[2].clone(), inputs[3].clone()])
        .unwrap()[0];
    let got = left.concat_last(r).channel_shuffle(2);
    let err = got.max_abs_diff(want);
    assert!(err < 1e-4, "shuffle basic split mismatch {err}");
}

#[test]
fn shuffle_reduce_split_equals_monolith() {
    // concat(left(FPGA f32), right(GPU)) + shuffle == shuffle_reduce_full
    let Some(rt) = runtime_or_skip() else { return };
    let full = rt.load("shuffle_reduce_full").unwrap();
    let gpu = rt.load("shuffle_reduce_gpu").unwrap();
    let fpga = rt.load("shuffle_reduce_fpga_f32").unwrap();

    // x, ld_w, l1_w, r1_w, rd_w, r2_w
    let inputs = rt.synth_inputs("shuffle_reduce_full", 23).unwrap();
    let want = &full.run(&inputs).unwrap()[0];

    let l = &fpga.run(&[inputs[0].clone(), inputs[1].clone(), inputs[2].clone()]).unwrap()[0];
    let r = &gpu
        .run(&[inputs[0].clone(), inputs[3].clone(), inputs[4].clone(), inputs[5].clone()])
        .unwrap()[0];
    let got = l.concat_last(r).channel_shuffle(2);
    let err = got.max_abs_diff(want);
    assert!(err < 1e-4, "shuffle reduce split mismatch {err}");
}

#[test]
fn gconv_artifact_runs() {
    let Some(rt) = runtime_or_skip() else { return };
    let exe = rt.load("gconv_g2").unwrap();
    let inputs = rt.synth_inputs("gconv_g2", 3).unwrap();
    let outs = exe.run(&inputs).unwrap();
    assert_eq!(outs[0].shape, vec![1, 28, 28, 48]);
}

#[test]
fn full_net_artifacts_classify() {
    // end-to-end: all three 224x224 nets produce finite 1000-class logits
    let Some(rt) = runtime_or_skip() else { return };
    for name in ["squeezenet_224", "mobilenetv2_05_224", "shufflenetv2_05_224"] {
        let exe = rt.load(name).expect(name);
        let inputs = rt.synth_inputs(name, 5).unwrap();
        let outs = exe.run(&inputs).expect(name);
        assert_eq!(outs[0].shape, vec![1, 1000], "{name}");
        assert!(outs[0].data.iter().all(|v| v.is_finite()), "{name}: non-finite logits");
        let spread = outs[0].data.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        assert!(spread > 1e-6, "{name}: all-zero logits");
    }
}
