//! Integration tests: the multi-model, batch-first Engine API.
//!
//! Everything here runs against the deterministic runtime (simulated
//! fallback when artifacts are not built), so the suite is exact: engine
//! outputs are compared **bit-for-bit** against direct per-request
//! `Executable::run` references — which the runtime unit tests in turn
//! prove identical to `run_batch`. Covers the ISSUE 2 acceptance
//! criteria: batch equivalence across pool sizes and registered models,
//! concurrent multi-model serving, priority ordering, deadline shedding,
//! and the wire protocol's structured errors.

use hetero_dnn::coordinator::server::{Client, Server};
use hetero_dnn::coordinator::{
    EngineBuilder, EngineHandle, InferenceRequest, ModelSpec, Priority,
};
use hetero_dnn::runtime::{Runtime, RuntimeError, Tensor};
use std::time::Duration;

/// The three module-level artifacts served throughout this suite:
/// (serving name, artifact, cost graph, input shape).
const MODELS: [(&str, &str, &str, [usize; 4]); 3] = [
    ("fire", "fire_full", "squeezenet", [1, 56, 56, 96]),
    ("bottleneck", "bottleneck_full", "mobilenetv2_05", [1, 28, 28, 16]),
    ("shuffle", "shuffle_basic_full", "shufflenetv2_05", [1, 28, 28, 48]),
];

fn multi_model_builder(workers: usize) -> EngineBuilder {
    let mut b = EngineBuilder::new().max_batch(4).max_wait(Duration::from_millis(5));
    for (name, artifact, graph, _) in MODELS {
        b = b.model(ModelSpec::new(name, artifact, graph).workers(workers));
    }
    b
}

fn multi_model_engine(workers: usize) -> EngineHandle {
    multi_model_builder(workers).build().expect("engine")
}

/// What the engine must return for `x` on `artifact` with seed-0 weights:
/// a direct, per-request execution on a private runtime.
fn reference_output(artifact: &str, x: &Tensor) -> Tensor {
    let rt = Runtime::new_or_simulated();
    let exe = rt.load(artifact).expect("load");
    let mut inputs = rt.synth_inputs(artifact, 0).expect("synth");
    inputs[0] = x.clone();
    exe.run(&inputs).expect("run").remove(0)
}

// ===========================================================================
// multi-model serving (acceptance: >= 2 models concurrently, correct and
// deterministic for each)

#[test]
fn two_models_serve_concurrent_clients_with_correct_deterministic_results() {
    let handle = multi_model_engine(2);
    let engine = handle.engine.clone();
    assert_eq!(engine.models(), vec!["fire", "bottleneck", "shuffle"]);
    assert_eq!(engine.default_model().as_deref(), Some("fire"));

    // 3 clients per model, 3 requests each, all in flight at once
    let mut joins = Vec::new();
    for (name, artifact, _, shape) in [MODELS[0], MODELS[1]] {
        for c in 0..3u64 {
            let engine = engine.clone();
            joins.push(std::thread::spawn(move || {
                (0..3u64)
                    .map(|i| {
                        let x = Tensor::randn(&shape, c * 100 + i);
                        let want = reference_output(artifact, &x);
                        let got = engine
                            .infer(InferenceRequest::new(name, x))
                            .expect("infer")
                            .output;
                        assert_eq!(
                            got.max_abs_diff(&want),
                            0.0,
                            "{name}: engine result must match direct execution"
                        );
                        got
                    })
                    .collect::<Vec<Tensor>>()
            }));
        }
    }
    let first_pass: Vec<Vec<Tensor>> = joins.into_iter().map(|j| j.join().unwrap()).collect();

    // a second identical pass must reproduce every output bit-for-bit
    for (mi, (name, _, _, shape)) in [MODELS[0], MODELS[1]].into_iter().enumerate() {
        for c in 0..3u64 {
            for i in 0..3u64 {
                let x = Tensor::randn(&shape, c * 100 + i);
                let again = engine.infer(InferenceRequest::new(name, x)).unwrap().output;
                let before = &first_pass[mi * 3 + c as usize][i as usize];
                assert_eq!(again.max_abs_diff(before), 0.0, "{name}: must be deterministic");
            }
        }
    }

    // 9 requests per model in the concurrent pass + 9 in the replay pass
    for (name, served) in [("fire", 18u64), ("bottleneck", 18u64)] {
        let metrics = engine.metrics(name).expect("registered");
        assert_eq!(metrics.lock().unwrap().served, served, "{name}");
    }
    drop(engine);
    handle.shutdown();
}

// ===========================================================================
// batch equivalence (satellite: batch-of-N == N independent runs, across
// pool sizes 1 and 4 and across all registered models)

#[test]
fn engine_batches_match_independent_runs_across_pool_sizes_and_models() {
    const N: u64 = 6;
    for workers in [1usize, 4] {
        // a generous window + concurrent submitters force multi-request
        // batches through the batch-first execution path
        // the window closes early once max_batch requests arrive, so the
        // generous 200 ms only bounds the slowest-spawn case
        let handle = multi_model_builder(workers)
            .max_batch(N as usize)
            .max_wait(Duration::from_millis(200))
            .build()
            .expect("engine");
        let engine = handle.engine.clone();
        for (name, artifact, _, shape) in MODELS {
            let mut joins = Vec::new();
            for i in 0..N {
                let engine = engine.clone();
                joins.push(std::thread::spawn(move || {
                    let x = Tensor::randn(&shape, 7_000 + i);
                    let resp = engine.infer(InferenceRequest::new(name, x.clone())).expect("infer");
                    (x, resp)
                }));
            }
            let mut max_batch_seen = 0;
            for j in joins {
                let (x, resp) = j.join().unwrap();
                let want = reference_output(artifact, &x);
                assert_eq!(
                    resp.output.max_abs_diff(&want),
                    0.0,
                    "{name} workers={workers}: batched result != independent run"
                );
                max_batch_seen = max_batch_seen.max(resp.batch_size);
            }
            assert!(
                max_batch_seen >= 2,
                "{name} workers={workers}: no multi-request batch ever formed \
                 (max batch {max_batch_seen}) — the batch path went untested"
            );
        }
        drop(engine);
        handle.shutdown();
    }
}

// ===========================================================================
// priorities and deadlines

#[test]
fn high_priority_executes_first_within_a_batch() {
    // one worker, batch of exactly 2, very long fill window: submit Low,
    // wait until the batcher holds it, then submit High — the formed
    // batch must order High before Low
    let handle = EngineBuilder::new()
        .max_batch(2)
        .max_wait(Duration::from_secs(5))
        .model(ModelSpec::new("fire", "fire_full", "squeezenet"))
        .build()
        .expect("engine");
    let engine = handle.engine.clone();

    let low = {
        let engine = engine.clone();
        std::thread::spawn(move || {
            engine
                .infer(
                    InferenceRequest::new("fire", Tensor::randn(&[1, 56, 56, 96], 1))
                        .with_priority(Priority::Low),
                )
                .expect("low infer")
        })
    };
    let t0 = std::time::Instant::now();
    while engine.accepted("fire").expect("registered") < 1 {
        assert!(t0.elapsed() < Duration::from_secs(10), "batcher never took the low request");
        std::thread::yield_now();
    }
    let high = engine
        .infer(
            InferenceRequest::new("fire", Tensor::randn(&[1, 56, 56, 96], 2))
                .with_priority(Priority::High),
        )
        .expect("high infer");
    let low = low.join().unwrap();

    assert_eq!(low.batch_size, 2, "both requests must share one batch");
    assert_eq!(high.batch_size, 2);
    assert_eq!(high.batch_index, 0, "high priority must lead the batch");
    assert_eq!(low.batch_index, 1, "low priority must trail the batch");
    drop(engine);
    handle.shutdown();
}

#[test]
fn past_deadline_requests_are_shed_not_executed() {
    // the lone request waits out the full 50 ms batching window, far past
    // its 1 ms deadline — the batcher must shed it instead of executing
    let handle = EngineBuilder::new()
        .max_batch(8)
        .max_wait(Duration::from_millis(50))
        .model(ModelSpec::new("fire", "fire_full", "squeezenet"))
        .build()
        .expect("engine");
    let engine = handle.engine.clone();

    let err = engine
        .infer(
            InferenceRequest::new("fire", Tensor::randn(&[1, 56, 56, 96], 1))
                .with_deadline(Duration::from_millis(1)),
        )
        .expect_err("must be shed");
    assert!(
        matches!(err, RuntimeError::DeadlineExceeded { .. }),
        "expected DeadlineExceeded, got: {err}"
    );
    assert_eq!(err.code(), "deadline");

    let metrics = engine.metrics("fire").expect("registered");
    {
        let m = metrics.lock().unwrap();
        assert_eq!(m.shed, 1, "shed must be counted");
        assert_eq!(m.served, 0, "a shed request must never execute");
    }

    // a deadline-free request on the same engine still serves normally
    let ok = engine
        .infer(InferenceRequest::new("fire", Tensor::randn(&[1, 56, 56, 96], 2)))
        .expect("deadline-free infer");
    assert_eq!(ok.output.shape, vec![1, 56, 56, 128]);
    drop(engine);
    handle.shutdown();
}

// ===========================================================================
// front-door validation and shared admission

#[test]
fn unknown_model_and_bad_shape_fail_before_queueing() {
    let handle = multi_model_engine(1);
    let engine = handle.engine.clone();

    let err = engine
        .infer(InferenceRequest::new("no_such_model", Tensor::zeros(&[1, 56, 56, 96])))
        .expect_err("unknown model must fail");
    match &err {
        RuntimeError::UnknownModel { name, registered } => {
            assert_eq!(name, "no_such_model");
            assert_eq!(registered, &["fire", "bottleneck", "shuffle"]);
        }
        other => panic!("expected UnknownModel, got {other}"),
    }
    assert_eq!(err.code(), "unknown_model");

    let err = engine
        .infer(InferenceRequest::new("fire", Tensor::zeros(&[1, 8, 8, 3])))
        .expect_err("bad shape must fail");
    assert!(matches!(err, RuntimeError::ShapeMismatch { .. }), "{err}");
    assert_eq!(err.code(), "shape_mismatch");

    // neither request may have reached a queue or a worker
    assert_eq!(engine.accepted("fire"), Some(0));
    let metrics = engine.metrics("fire").expect("registered");
    {
        let m = metrics.lock().unwrap();
        assert_eq!(m.served + m.errors + m.batches, 0);
    }
    drop(engine);
    handle.shutdown();
}

#[test]
fn admission_is_shared_across_models() {
    use hetero_dnn::coordinator::admission::AdmissionConfig;
    let handle = multi_model_builder(1)
        .admission(AdmissionConfig::default())
        .build()
        .expect("engine");
    let engine = handle.engine.clone();
    for (name, _, _, shape) in [MODELS[0], MODELS[1]] {
        engine
            .infer(InferenceRequest::new(name, Tensor::randn(&shape, 1)))
            .expect("infer");
    }
    let ctl = engine.admission().expect("admission configured");
    assert_eq!(
        ctl.admitted.load(std::sync::atomic::Ordering::Relaxed),
        2,
        "one shared controller must have admitted both models' requests"
    );
    assert_eq!(ctl.in_flight(), 0, "both requests completed");
    drop(engine);
    handle.shutdown();
}

// ===========================================================================
// the completion-order seam (Engine::submit) the pipelined wire protocol
// is built on

#[test]
fn submit_delivers_tagged_completions_without_blocking() {
    let handle = multi_model_builder(2)
        .max_batch(8)
        .max_wait(Duration::from_millis(50))
        .build()
        .expect("engine");
    let engine = handle.engine.clone();
    let (sink, completions) = std::sync::mpsc::channel();

    // 8 submits return immediately; responses arrive through the sink
    let mut inputs = std::collections::HashMap::new();
    for tag in 0..8u64 {
        let x = Tensor::randn(&MODELS[0].3, 500 + tag);
        engine
            .submit(InferenceRequest::new("fire", x.clone()), tag, &sink)
            .expect("submit accepts");
        inputs.insert(tag, x);
    }
    for _ in 0..8 {
        let done = completions.recv().expect("completion");
        let resp = done.result.expect("served");
        let x = inputs.remove(&done.tag).expect("tag matches a submit");
        assert_eq!(
            resp.output.max_abs_diff(&reference_output(MODELS[0].1, &x)),
            0.0,
            "completion must answer the request carrying ITS tag"
        );
    }
    assert!(inputs.is_empty(), "every submit completed exactly once");

    // front-door rejections are synchronous and never reach the sink
    let err = engine
        .submit(
            InferenceRequest::new("no_such_model", Tensor::zeros(&[1, 56, 56, 96])),
            99,
            &sink,
        )
        .expect_err("unknown model must fail at the front door");
    assert_eq!(err.code(), "unknown_model");
    assert!(
        completions.try_recv().is_err(),
        "a front-door rejection must not produce a completion"
    );
    drop(engine);
    handle.shutdown();
}

// ===========================================================================
// wire protocol: model routing + structured errors (satellite: unknown
// model / bad shape answer with a JSON error frame and keep the
// connection open)

#[test]
fn server_routes_models_and_structured_errors_keep_connection_open() {
    let handle = multi_model_engine(1);
    let engine = handle.engine.clone();
    let server = Server::start("127.0.0.1:0", engine.clone()).expect("server");
    let mut client = Client::connect(&server.addr).expect("connect");

    // 1. unknown model: structured error, connection survives
    let x_fire = Tensor::randn(&[1, 56, 56, 96], 3);
    let err = client.infer_model(Some("no_such_model"), &x_fire).expect_err("must error");
    assert!(err.to_string().contains("unknown_model"), "{err}");

    // 2. the SAME connection serves a valid request afterwards
    let resp = client.infer_model(Some("fire"), &x_fire).expect("connection must survive");
    assert_eq!(resp.model, "fire");
    assert_eq!(resp.output.max_abs_diff(&reference_output("fire_full", &x_fire)), 0.0);

    // 3. shape mismatch: structured error, connection survives again
    let err = client
        .infer_model(Some("fire"), &Tensor::zeros(&[1, 8, 8, 3]))
        .expect_err("bad shape must error");
    assert!(err.to_string().contains("shape_mismatch"), "{err}");

    // 4. explicit routing to a second model on the same connection
    let x_bn = Tensor::randn(&[1, 28, 28, 16], 4);
    let resp = client.infer_model(Some("bottleneck"), &x_bn).expect("bottleneck infer");
    assert_eq!(resp.model, "bottleneck");
    assert_eq!(resp.output.shape, vec![1, 28, 28, 16]);
    assert_eq!(resp.output.max_abs_diff(&reference_output("bottleneck_full", &x_bn)), 0.0);

    // 5. no model field -> the default (first registered) model
    let resp = client.infer(&x_fire).expect("default model infer");
    assert_eq!(resp.model, "fire");

    server.stop();
    handle.shutdown();
}

// ===========================================================================
// panic safety at the dispatch boundary (satellite: a poisoned worker
// answers its batch with serving errors, keeps serving, and no longer
// deadlocks Engine::shutdown)

#[test]
fn poisoned_worker_replies_with_errors_and_shutdown_still_joins() {
    use hetero_dnn::coordinator::step::inject_dispatch_panic;

    // a uniquely named pool so the keyed injection cannot be consumed by
    // (or leak into) any other pool in this concurrently-running binary
    let handle = EngineBuilder::new()
        .max_batch(4)
        .max_wait(Duration::from_millis(5))
        .model(ModelSpec::new("poisoned_fire", "fire_full", "squeezenet").workers(1))
        .build()
        .expect("engine");
    let engine = handle.engine.clone();

    // 1. the injected executor panic must come back as a per-request
    //    serving error, not a hung client
    inject_dispatch_panic("poisoned_fire");
    let x = Tensor::randn(&[1, 56, 56, 96], 11);
    let err = engine
        .infer(InferenceRequest::new("poisoned_fire", x.clone()))
        .expect_err("a panicking executor must surface as an error reply");
    assert!(
        matches!(err, RuntimeError::Serving(_)),
        "panic must map to a serving error, got {err:?}"
    );
    assert!(err.to_string().contains("executor panicked"), "{err}");
    assert!(err.to_string().contains("injected dispatch panic"), "{err}");

    // 2. the worker thread survived the panic: the SAME pool keeps
    //    serving correct results afterwards
    let resp = engine
        .infer(InferenceRequest::new("poisoned_fire", x.clone()))
        .expect("pool must survive a contained panic");
    assert_eq!(resp.output.max_abs_diff(&reference_output("fire_full", &x)), 0.0);

    let metrics = engine.metrics("poisoned_fire").expect("registered");
    {
        let m = metrics.lock().unwrap();
        assert_eq!(m.errors, 1, "exactly the poisoned batch errored");
        assert_eq!(m.served, 1, "the follow-up request served normally");
    }

    // 3. shutdown joins: before panic containment this deadlocked on the
    //    dead worker's never-closed reply channels
    drop(engine);
    handle.shutdown();
}
