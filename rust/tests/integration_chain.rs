//! The flagship end-to-end functional test: executing SqueezeNet through
//! the per-module artifact chain — monolithic vs the paper's heterogeneous
//! dataflow (GPU part -> int8 PCIe boundary -> FPGA DHM part -> concat) —
//! must leave the classification output intact.
//!
//! Requires `make artifacts` (skipped otherwise).

use hetero_dnn::config::Manifest;
use hetero_dnn::runtime::chain::{ChainExecutor, FpgaPrecision};
use hetero_dnn::runtime::{Runtime, Tensor};

fn runtime_or_skip() -> Option<Runtime> {
    match Manifest::load() {
        Ok(m) if m.artifacts.contains_key("sq_stem") => {
            let rt = Runtime::new().expect("runtime");
            if rt.has_real_backend() {
                Some(rt)
            } else {
                // monolithic-vs-hetero equivalence is a claim about the real
                // kernels; the deterministic stand-in cannot satisfy it
                eprintln!("no real (PJRT) backend in this build; skipping chain tests");
                None
            }
        }
        _ => {
            eprintln!("chain artifacts not built; skipping");
            None
        }
    }
}

#[test]
fn chain_monolithic_matches_single_artifact_net() {
    // module-by-module execution == the one-artifact squeezenet_224
    let Some(rt) = runtime_or_skip() else { return };
    let chain = ChainExecutor::new(&rt, 7).expect("chain");
    let x = Tensor::randn(&[1, 224, 224, 3], 99);

    let by_modules = chain.run_monolithic(&x).expect("chain run");

    let net = rt.load("squeezenet_224").expect("net");
    let mut inputs = vec![x];
    inputs.extend(chain.flat_weights());
    let whole = &net.run(&inputs).expect("net run")[0];

    let err = by_modules.max_abs_diff(whole);
    assert!(err < 1e-3, "module chain deviates from monolithic net: {err}");
}

#[test]
fn chain_hetero_f32_is_exact() {
    // float split: partitioning must be EXACTLY output-preserving
    let Some(rt) = runtime_or_skip() else { return };
    let chain = ChainExecutor::new(&rt, 11).expect("chain");
    let x = Tensor::randn(&[1, 224, 224, 3], 5);
    let mono = chain.run_monolithic(&x).expect("mono");
    let het = chain.run_hetero(&x, FpgaPrecision::F32).expect("hetero f32");
    let err = het.max_abs_diff(&mono);
    assert!(err < 1e-4, "f32 hetero execution deviates: {err}");
}

#[test]
fn chain_hetero_int8_tracks_float() {
    // the REAL paper dataflow: int8 link + 8-bit DHM arithmetic on every
    // fire module; classification logits must survive within quant noise
    let Some(rt) = runtime_or_skip() else { return };
    let chain = ChainExecutor::new(&rt, 13).expect("chain");
    let x = Tensor::randn(&[1, 224, 224, 3], 17);
    let mono = chain.run_monolithic(&x).expect("mono");
    let het = chain.run_hetero(&x, FpgaPrecision::Int8).expect("hetero int8");

    assert!(het.data.iter().all(|v| v.is_finite()));
    let rel = het.rel_error(&mono);
    assert!(rel < 0.15, "int8 hetero path diverges: rel {rel}");

    // top-1 agreement: the argmax class must survive 8 stages of int8
    let argmax = |t: &Tensor| {
        t.data
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap()
    };
    assert_eq!(argmax(&het), argmax(&mono), "top-1 class flipped under int8 path");
}

#[test]
fn chain_deterministic() {
    let Some(rt) = runtime_or_skip() else { return };
    let chain = ChainExecutor::new(&rt, 3).expect("chain");
    let x = Tensor::randn(&[1, 224, 224, 3], 1);
    let a = chain.run_hetero(&x, FpgaPrecision::Int8).expect("a");
    let b = chain.run_hetero(&x, FpgaPrecision::Int8).expect("b");
    assert_eq!(a.max_abs_diff(&b), 0.0);
}

#[test]
fn chain_weight_seeds_differ() {
    let Some(rt) = runtime_or_skip() else { return };
    let a = ChainExecutor::new(&rt, 1).expect("a");
    let b = ChainExecutor::new(&rt, 2).expect("b");
    let x = Tensor::randn(&[1, 224, 224, 3], 1);
    let ya = a.run_monolithic(&x).expect("ya");
    let yb = b.run_monolithic(&x).expect("yb");
    assert!(ya.max_abs_diff(&yb) > 0.0, "different weights must differ");
}
