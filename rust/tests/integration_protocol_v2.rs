//! Integration tests: wire protocol v2 (PROTOCOL.md) end to end.
//!
//! Covers the ISSUE 4 acceptance criteria: HELLO negotiation and the
//! model-table snapshot, a pipelined connection sustaining >= 8 requests
//! in flight with responses matched by id in completion order, streamed
//! chunked responses assembled bit-identically to direct execution, v1
//! clients interoperating with the v2 server unchanged (version-sniff
//! fallback), error frames matched by id that keep the session open, and
//! fatal framing faults (bad magic / bad kind / oversized tensor) that
//! close it — plus the regression test for the v1 client's
//! truncated-response bug (a mid-frame server close must surface as an
//! error, never as a silently zero-filled tensor).

use hetero_dnn::coordinator::protocol::{self, AsyncClient, Reply, StreamReply};
use hetero_dnn::coordinator::server::{Client, Server, ServerConfig};
use hetero_dnn::coordinator::{EngineBuilder, EngineHandle, ModelSpec, Priority};
use hetero_dnn::runtime::{Runtime, Tensor};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

const FIRE_SHAPE: [usize; 4] = [1, 56, 56, 96];
const BN_SHAPE: [usize; 4] = [1, 28, 28, 16];

fn fire_engine(max_batch: usize, max_wait: Duration) -> EngineHandle {
    EngineBuilder::new()
        .max_batch(max_batch)
        .max_wait(max_wait)
        .model(ModelSpec::new("fire", "fire_full", "squeezenet"))
        .build()
        .expect("engine")
}

/// What the engine must return for `x` on `artifact` with seed-0 weights.
fn reference_output(artifact: &str, x: &Tensor) -> Tensor {
    let rt = Runtime::new_or_simulated();
    let exe = rt.load(artifact).expect("load");
    let mut inputs = rt.synth_inputs(artifact, 0).expect("synth");
    inputs[0] = x.clone();
    exe.run(&inputs).expect("run").remove(0)
}

/// Raw v2 handshake against a one-model `fire` server; asserts the
/// HELLO_ACK matches the codec byte-for-byte.
fn raw_handshake(addr: &std::net::SocketAddr) -> TcpStream {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.write_all(&protocol::encode_hello()).expect("hello");
    let expected =
        protocol::encode_hello_ack(protocol::VERSION, &[("fire".to_string(), FIRE_SHAPE.to_vec())]);
    let mut ack = vec![0u8; expected.len()];
    s.read_exact(&mut ack).expect("hello_ack");
    assert_eq!(ack, expected, "HELLO_ACK must match the codec byte-for-byte");
    s
}

/// Read one ERROR frame off a raw stream: (id, code, fatal).
fn read_error_frame(s: &mut TcpStream) -> (u64, String, bool) {
    let mut pre = [0u8; 8];
    s.read_exact(&mut pre).expect("error prelude");
    let p = protocol::parse_prelude(&pre).expect("prelude parses");
    assert_eq!(p.kind, protocol::KIND_ERROR, "expected an ERROR frame");
    let mut body = [0u8; 16];
    s.read_exact(&mut body).expect("error body");
    let id = u64::from_le_bytes(body[..8].try_into().unwrap());
    let code_len = u16::from_le_bytes([body[8], body[9]]) as usize;
    let msg_len = u16::from_le_bytes([body[10], body[11]]) as usize;
    let mut rest = vec![0u8; code_len + msg_len];
    s.read_exact(&mut rest).expect("error strings");
    let code = String::from_utf8_lossy(&rest[..code_len]).into_owned();
    (id, code, p.flags & protocol::FLAG_FATAL != 0)
}

fn assert_eof(s: &mut TcpStream) {
    let mut byte = [0u8; 1];
    assert_eq!(s.read(&mut byte).expect("read at eof"), 0, "server must close after a fatal frame");
}

// ===========================================================================
// negotiation

#[test]
fn hello_negotiation_reports_version_and_model_table() {
    let handle = EngineBuilder::new()
        .model(ModelSpec::new("fire", "fire_full", "squeezenet"))
        .model(ModelSpec::new("bottleneck", "bottleneck_full", "mobilenetv2_05"))
        .build()
        .expect("engine");
    let server = Server::start("127.0.0.1:0", handle.engine.clone()).expect("server");
    let client = AsyncClient::connect(&server.addr).expect("connect");
    assert_eq!(client.version(), protocol::VERSION);
    assert_eq!(
        client.models(),
        &[
            ("fire".to_string(), FIRE_SHAPE.to_vec()),
            ("bottleneck".to_string(), BN_SHAPE.to_vec()),
        ]
    );
    assert_eq!(client.in_flight(), 0);
    drop(client);
    server.stop();
    handle.shutdown();
}

#[test]
fn v1_client_interoperates_with_v2_server_unchanged() {
    // the negotiation fallback: a v1 JSON client never sends HELLO; the
    // server sniffs the length prefix and speaks v1 for the connection
    let handle = fire_engine(4, Duration::from_millis(2));
    let server = Server::start("127.0.0.1:0", handle.engine.clone()).expect("server");
    let mut client = Client::connect(&server.addr).expect("v1 connect");
    let x = Tensor::randn(&FIRE_SHAPE, 3);
    let resp = client.infer(&x).expect("v1 infer against the v2 server");
    assert_eq!(resp.model, "fire");
    assert_eq!(resp.output.max_abs_diff(&reference_output("fire_full", &x)), 0.0);
    // …and a v2 client works on the same server concurrently
    let mut v2 = AsyncClient::connect(&server.addr).expect("v2 connect");
    let id = v2.submit(Some("fire"), &x).expect("submit");
    match v2.recv().expect("recv") {
        Reply::Response(r) => {
            assert_eq!(r.id, id);
            assert_eq!(r.output.max_abs_diff(&reference_output("fire_full", &x)), 0.0);
        }
        Reply::Error { code, message, .. } => panic!("{code}: {message}"),
    }
    server.stop();
    handle.shutdown();
}

#[test]
fn v1_only_server_rejects_hello_but_serves_v1() {
    let handle = fire_engine(4, Duration::from_millis(2));
    let cfg = ServerConfig { v2: false, ..ServerConfig::default() };
    let server = Server::start_with("127.0.0.1:0", handle.engine.clone(), cfg).expect("server");
    let err = AsyncClient::connect(&server.addr).expect_err("HELLO must be rejected");
    assert!(err.to_string().contains("unsupported_version"), "{err}");
    let mut client = Client::connect(&server.addr).expect("v1 connect");
    let x = Tensor::randn(&FIRE_SHAPE, 4);
    assert!(client.infer(&x).is_ok(), "v1 must still be served");
    server.stop();
    handle.shutdown();
}

// ===========================================================================
// pipelining (acceptance: >= 8 in flight on one connection, responses
// matched by id in completion order)

#[test]
fn pipelined_connection_sustains_eight_in_flight_matched_by_id() {
    const DEPTH: u64 = 8;
    let handle = fire_engine(DEPTH as usize, Duration::from_millis(200));
    let server = Server::start("127.0.0.1:0", handle.engine.clone()).expect("server");
    let mut client = AsyncClient::connect(&server.addr).expect("connect");

    let mut inputs: HashMap<u64, Tensor> = HashMap::new();
    for seed in 0..DEPTH {
        let x = Tensor::randn(&FIRE_SHAPE, seed);
        let id = client.submit(Some("fire"), &x).expect("submit");
        inputs.insert(id, x);
    }
    assert_eq!(client.in_flight(), DEPTH as usize, "all 8 must be in flight at once");

    let mut max_batch_seen = 0;
    for _ in 0..DEPTH {
        match client.recv().expect("recv") {
            Reply::Response(r) => {
                let x = inputs.remove(&r.id).expect("response id matches a pending submit");
                assert_eq!(
                    r.output.max_abs_diff(&reference_output("fire_full", &x)),
                    0.0,
                    "pipelined result must match direct execution for ITS request"
                );
                max_batch_seen = max_batch_seen.max(r.batch_size);
            }
            Reply::Error { id, code, message, .. } => panic!("request {id}: {code}: {message}"),
        }
    }
    assert!(inputs.is_empty(), "every submit must be answered exactly once");
    assert_eq!(client.in_flight(), 0);
    assert!(
        max_batch_seen >= 2,
        "pipelined requests never shared a batch (max {max_batch_seen}) — \
         pipelining failed to feed the batcher"
    );
    server.stop();
    handle.shutdown();
}

#[test]
fn pipelining_interleaves_two_models_on_one_connection() {
    let handle = EngineBuilder::new()
        .max_batch(4)
        .max_wait(Duration::from_millis(5))
        .model(ModelSpec::new("fire", "fire_full", "squeezenet"))
        .model(ModelSpec::new("bottleneck", "bottleneck_full", "mobilenetv2_05"))
        .build()
        .expect("engine");
    let server = Server::start("127.0.0.1:0", handle.engine.clone()).expect("server");
    let mut client = AsyncClient::connect(&server.addr).expect("connect");

    let mut expect: HashMap<u64, (&str, Tensor)> = HashMap::new();
    for i in 0..8u64 {
        let (model, artifact, shape): (&str, &str, &[usize]) = if i % 2 == 0 {
            ("fire", "fire_full", &FIRE_SHAPE)
        } else {
            ("bottleneck", "bottleneck_full", &BN_SHAPE)
        };
        let x = Tensor::randn(shape, 100 + i);
        let id = client.submit(Some(model), &x).expect("submit");
        expect.insert(id, (artifact, x));
    }
    for _ in 0..8 {
        match client.recv().expect("recv") {
            Reply::Response(r) => {
                let (artifact, x) = expect.remove(&r.id).expect("known id");
                assert_eq!(r.output.max_abs_diff(&reference_output(artifact, &x)), 0.0);
            }
            Reply::Error { id, code, message, .. } => panic!("request {id}: {code}: {message}"),
        }
    }
    assert!(expect.is_empty());
    server.stop();
    handle.shutdown();
}

// ===========================================================================
// streaming

#[test]
fn streamed_chunks_assemble_bit_identically() {
    // fire_full's output is 1x56x56x128 = 401408 elements; a 50k-element
    // chunk size forces a head frame + 8 continuations
    const CHUNK: usize = 50_000;
    let handle = fire_engine(4, Duration::ZERO);
    let cfg = ServerConfig { chunk_elems: CHUNK, ..ServerConfig::default() };
    let server = Server::start_with("127.0.0.1:0", handle.engine.clone(), cfg).expect("server");
    let mut client = AsyncClient::connect(&server.addr).expect("connect");

    let x = Tensor::randn(&FIRE_SHAPE, 11);
    let id = client.submit(Some("fire"), &x).expect("submit");
    let stream = match client.recv_streaming().expect("recv_streaming") {
        StreamReply::Stream(s) => s,
        StreamReply::Error { code, message, .. } => panic!("{code}: {message}"),
    };
    let total: usize = stream.head().shape.iter().product();
    assert_eq!(stream.head().id, id);
    assert_eq!(stream.head().model, "fire");
    assert_eq!(total, 401_408);

    // consume incrementally: every chunk bounded by the configured size,
    // counts summing exactly to the full tensor
    let mut stream = stream;
    let shape = stream.head().shape.clone();
    let (mut chunks, mut elems, mut data) = (0usize, 0usize, Vec::with_capacity(total));
    while let Some(chunk) = stream.next_chunk().expect("next_chunk") {
        assert!(chunk.len() <= CHUNK, "chunk of {} exceeds the configured size", chunk.len());
        chunks += 1;
        elems += chunk.len();
        data.extend_from_slice(&chunk);
    }
    assert_eq!(chunks, total.div_ceil(CHUNK), "expected head + continuations");
    assert_eq!(elems, total);
    // fully consumed: dropping the stream releases the client unpoisoned
    drop(stream);
    let got = Tensor::new(shape, data);
    assert_eq!(
        got.max_abs_diff(&reference_output("fire_full", &x)),
        0.0,
        "streamed chunks must reassemble to the exact execution result"
    );

    // the connection survives a fully-consumed stream
    let id2 = client.submit(Some("fire"), &x).expect("submit again");
    match client.recv().expect("recv") {
        Reply::Response(r) => assert_eq!(r.id, id2),
        Reply::Error { code, message, .. } => panic!("{code}: {message}"),
    }
    server.stop();
    handle.shutdown();
}

// ===========================================================================
// error frames: matched by id, recoverable vs fatal

#[test]
fn error_frames_are_matched_by_id_and_keep_the_session_open() {
    let handle = EngineBuilder::new()
        .max_batch(8)
        .max_wait(Duration::from_millis(60))
        .model(ModelSpec::new("fire", "fire_full", "squeezenet"))
        .model(ModelSpec::new("bottleneck", "bottleneck_full", "mobilenetv2_05"))
        .build()
        .expect("engine");
    let engine = handle.engine.clone();
    let server = Server::start("127.0.0.1:0", engine.clone()).expect("server");
    let mut client = AsyncClient::connect(&server.addr).expect("connect");

    // 1. a queue-time deadline that must expire inside the 60 ms window
    let x_fire = Tensor::randn(&FIRE_SHAPE, 21);
    let shed_id = client
        .submit_with(Some("fire"), &x_fire, Priority::Normal, Some(Duration::from_micros(1)))
        .expect("submit");
    match client.recv().expect("recv") {
        Reply::Error { id, code, fatal, .. } => {
            assert_eq!(id, shed_id, "error frames must carry the request's id");
            assert_eq!(code, "deadline");
            assert!(!fatal, "a shed request is not a framing fault");
        }
        Reply::Response(r) => panic!("deadline-doomed request {} served", r.id),
    }

    // 2. retire a model the connection's table still lists: requests to
    // it answer unknown_model, matched by id, session open
    engine.retire("bottleneck").expect("retire");
    let x_bn = Tensor::randn(&BN_SHAPE, 22);
    let gone_id = client.submit(Some("bottleneck"), &x_bn).expect("submit to retired");
    match client.recv().expect("recv") {
        Reply::Error { id, code, fatal, .. } => {
            assert_eq!(id, gone_id);
            assert_eq!(code, "unknown_model");
            assert!(!fatal);
        }
        Reply::Response(r) => panic!("retired model served request {}", r.id),
    }

    // 3. the SAME connection still serves the live model
    let ok_id = client.submit(Some("fire"), &x_fire).expect("submit after errors");
    match client.recv().expect("recv") {
        Reply::Response(r) => {
            assert_eq!(r.id, ok_id);
            assert_eq!(r.output.max_abs_diff(&reference_output("fire_full", &x_fire)), 0.0);
        }
        Reply::Error { code, message, .. } => panic!("{code}: {message}"),
    }
    server.stop();
    handle.shutdown();
}

#[test]
fn bad_magic_on_a_v1_connection_closes_with_bad_frame() {
    let handle = fire_engine(4, Duration::from_millis(2));
    let server = Server::start("127.0.0.1:0", handle.engine.clone()).expect("server");
    let mut s = TcpStream::connect(&server.addr).expect("connect");
    // not the magic, and far beyond the v1 header bound
    s.write_all(&0xFFFF_FFFFu32.to_le_bytes()).expect("garbage");
    let mut len4 = [0u8; 4];
    s.read_exact(&mut len4).expect("v1 error frame length");
    let mut header = vec![0u8; u32::from_le_bytes(len4) as usize];
    s.read_exact(&mut header).expect("v1 error frame header");
    let header = String::from_utf8_lossy(&header).into_owned();
    assert!(header.contains("bad_frame"), "{header}");
    assert_eof(&mut s);
    server.stop();
    handle.shutdown();
}

#[test]
fn unknown_v2_frame_kind_is_a_fatal_bad_frame() {
    let handle = fire_engine(4, Duration::from_millis(2));
    let server = Server::start("127.0.0.1:0", handle.engine.clone()).expect("server");
    let mut s = raw_handshake(&server.addr);
    // magic + version 2 + undefined kind 0x7f (0x07 became HEALTH)
    s.write_all(&[b'H', b'D', b'P', b'2', 2, 0x7f, 0, 0]).expect("bad kind frame");
    let (id, code, fatal) = read_error_frame(&mut s);
    assert_eq!((id, code.as_str(), fatal), (0, "bad_frame", true));
    assert_eof(&mut s);
    server.stop();
    handle.shutdown();
}

#[test]
fn oversized_request_is_a_fatal_bad_frame_matched_by_id() {
    let handle = fire_engine(4, Duration::from_millis(2));
    let server = Server::start("127.0.0.1:0", handle.engine.clone()).expect("server");
    let mut s = raw_handshake(&server.addr);
    let header = protocol::RequestHeader {
        id: 5,
        model: 0,
        priority: 0,
        deadline_us: 0,
        dims: vec![4096, 4096, 4096], // 2^36 elements >> the 2^24 bound
    };
    s.write_all(&protocol::encode_request_header(&header)).expect("oversized header");
    let (id, code, fatal) = read_error_frame(&mut s);
    assert_eq!((id, code.as_str(), fatal), (5, "bad_frame", true));
    assert_eof(&mut s);
    server.stop();
    handle.shutdown();
}

#[test]
fn fatal_frame_waits_for_in_flight_responses() {
    // a framing fault must not eat responses already accepted: the
    // in-flight request is answered first, the fatal frame is last
    let handle = fire_engine(4, Duration::from_millis(2));
    let server = Server::start("127.0.0.1:0", handle.engine.clone()).expect("server");
    let mut s = raw_handshake(&server.addr);
    let x = Tensor::randn(&FIRE_SHAPE, 31);
    let req = protocol::RequestHeader {
        id: 77,
        model: 0,
        priority: 0,
        deadline_us: 0,
        dims: FIRE_SHAPE.to_vec(),
    };
    s.write_all(&protocol::encode_request(&req, &x.data)).expect("valid request");
    // immediately poison the stream with an undefined kind (0x7f —
    // 0x07 became HEALTH)
    s.write_all(&[b'H', b'D', b'P', b'2', 2, 0x7f, 0, 0]).expect("bad kind frame");

    // first: the full response for id 77 (head + chunks)
    let mut pre = [0u8; 8];
    s.read_exact(&mut pre).expect("response prelude");
    let p = protocol::parse_prelude(&pre).expect("prelude");
    assert_eq!(p.kind, protocol::KIND_RESPONSE, "in-flight response must arrive before the fatal");
    let mut body = vec![0u8; 36 + p.rank as usize * 4];
    s.read_exact(&mut body).expect("response body");
    let h = protocol::decode_response_body(&p, &body).expect("response decodes");
    assert_eq!(h.id, 77);
    let total: usize = h.dims.iter().product();
    let mut consumed = h.chunk_elems as usize;
    let mut skip = vec![0u8; h.chunk_elems as usize * 4];
    s.read_exact(&mut skip).expect("first chunk payload");
    let mut last = h.last;
    while !last {
        let mut pre = [0u8; 8];
        s.read_exact(&mut pre).expect("chunk prelude");
        let p = protocol::parse_prelude(&pre).expect("chunk prelude parses");
        assert_eq!(p.kind, protocol::KIND_CHUNK);
        let mut cbody = [0u8; 16];
        s.read_exact(&mut cbody).expect("chunk body");
        let elems = u32::from_le_bytes([cbody[12], cbody[13], cbody[14], cbody[15]]) as usize;
        let mut payload = vec![0u8; elems * 4];
        s.read_exact(&mut payload).expect("chunk payload");
        consumed += elems;
        last = p.flags & protocol::FLAG_LAST != 0;
    }
    assert_eq!(consumed, total, "the in-flight response must arrive complete");
    // then: the fatal frame, as the connection's last bytes
    let (id, code, fatal) = read_error_frame(&mut s);
    assert_eq!((id, code.as_str(), fatal), (0, "bad_frame", true));
    assert_eof(&mut s);
    server.stop();
    handle.shutdown();
}

// ===========================================================================
// v1 client truncation regression (the satellite bugfix)

#[test]
fn truncated_v1_response_is_an_error_not_a_zero_tensor() {
    // a fake server that promises a [1, 4] payload but closes after two
    // bytes: the old client zero-filled the tensor silently
    let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("addr");
    let fake = std::thread::spawn(move || {
        let (mut s, _) = listener.accept().expect("accept");
        let header = "{\"id\":0,\"shape\":[1,4]}";
        s.write_all(&(header.len() as u32).to_le_bytes()).expect("len");
        s.write_all(header.as_bytes()).expect("header");
        s.write_all(&[0x00, 0x00]).expect("half an f32");
        // drop: the connection closes mid-payload
    });
    let mut client = Client::connect(&addr).expect("connect");
    let err = client
        .infer(&Tensor::zeros(&[1, 2]))
        .expect_err("a truncated response must surface as an error");
    assert_eq!(err.kind(), std::io::ErrorKind::UnexpectedEof, "{err}");
    fake.join().expect("fake server");
}

#[test]
fn truncated_v1_response_header_is_an_error() {
    // same bug, earlier in the frame: the header itself is cut short
    let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("addr");
    let fake = std::thread::spawn(move || {
        let (mut s, _) = listener.accept().expect("accept");
        s.write_all(&50u32.to_le_bytes()).expect("len");
        s.write_all(b"0123456789").expect("10 of 50 header bytes");
    });
    let mut client = Client::connect(&addr).expect("connect");
    let err = client
        .infer(&Tensor::zeros(&[1, 2]))
        .expect_err("a truncated header must surface as an error");
    assert_eq!(err.kind(), std::io::ErrorKind::UnexpectedEof, "{err}");
    fake.join().expect("fake server");
}

// ===========================================================================
// recv_deadline: telling a slow replica from a dead one (the cluster
// router's failover input — ISSUE 7)

/// A silent upstream must be distinguishable from a closed one: a
/// deadline expiring before any response byte is a clean timeout
/// ([`protocol::is_timeout`]) that leaves the connection usable for the
/// next probe, while the peer half-closing the socket is an
/// `UnexpectedEof` — the router treats only the latter as replica-down.
#[test]
fn recv_deadline_times_out_clean_on_silence_and_eofs_on_close() {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("addr");
    let (close_tx, close_rx) = std::sync::mpsc::channel::<()>();
    let fake = std::thread::spawn(move || {
        let (mut s, _) = listener.accept().expect("accept");
        let mut hello = vec![0u8; protocol::encode_hello().len()];
        s.read_exact(&mut hello).expect("client hello");
        s.write_all(&protocol::encode_hello_ack(
            protocol::VERSION,
            &[("m".to_string(), vec![1, 2])],
        ))
        .expect("hello ack");
        // swallow the request, then go silent until told to die
        let mut req = [0u8; 64];
        let _ = s.read(&mut req);
        close_rx.recv().expect("close signal");
        // dropping the socket here half-closes it: the client sees EOF
    });

    let mut client = AsyncClient::connect(&addr).expect("connect");
    assert_eq!(client.models(), &[("m".to_string(), vec![1, 2])]);
    client.submit(None, &Tensor::randn(&[1, 2], 0)).expect("submit");

    // slow replica: the deadline expires before any response byte — a
    // clean timeout, and the connection stays usable for another probe
    let err = client.recv_deadline(Duration::from_millis(150)).expect_err("must time out");
    assert!(protocol::is_timeout(&err), "expected a timeout, got {err}");
    let err = client.recv_deadline(Duration::from_millis(150)).expect_err("must time out again");
    assert!(protocol::is_timeout(&err), "a clean timeout must not poison, got {err}");

    // dead replica: the peer closes — an EOF, never mistaken for slow
    close_tx.send(()).expect("signal close");
    fake.join().expect("fake server");
    let err = client.recv_deadline(Duration::from_secs(1)).expect_err("must EOF");
    assert!(!protocol::is_timeout(&err), "EOF must not look like a timeout: {err}");
    assert_eq!(err.kind(), std::io::ErrorKind::UnexpectedEof, "{err}");
}
