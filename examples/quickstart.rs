//! Quickstart: the smallest end-to-end tour of the public API.
//!
//! 1. Load an AOT artifact (HLO text lowered from the L1 Pallas conv
//!    kernel) and execute it — the *functional* half. Without built
//!    artifacts the simulated platform runtime steps in automatically.
//! 2. Cost the same convolution on the three device models and print the
//!    paper's Fig-1-style comparison — the *platform* half.
//! 3. Serve one request through the batch-first [`Engine`] — the
//!    *serving* half (EngineBuilder -> infer -> shutdown).
//!
//! Run: `cargo run --release --example quickstart`

use hetero_dnn::coordinator::{EngineBuilder, InferenceRequest, ModelSpec};
use hetero_dnn::graph::{Activation, Layer, OpKind, TensorShape};
use hetero_dnn::link::Precision;
use hetero_dnn::partition::Planner;
use hetero_dnn::runtime::{Runtime, Tensor};

fn main() -> anyhow::Result<()> {
    // --- functional: run the conv3x3 artifact (simulated fallback when
    //     artifacts are not built)
    let rt = Runtime::new_or_simulated();
    println!("runtime platform: {}", rt.platform());
    let exe = rt.load("conv3x3")?;
    let inputs = rt.synth_inputs("conv3x3", 0)?;
    let t0 = std::time::Instant::now();
    let outs = exe.run(&inputs)?;
    println!(
        "conv3x3: {:?} x {:?} -> {:?} in {:?}",
        inputs[0].shape,
        inputs[1].shape,
        outs[0].shape,
        t0.elapsed()
    );

    // ...and its 8-bit DHM-datapath twin, checking the quantization error
    let q8 = rt.load("conv3x3_q8")?;
    let outs_q8 = q8.run(&inputs)?;
    println!("conv3x3_q8 rel. error vs float: {:.4}", outs_q8[0].rel_error(&outs[0]));

    // --- platform: what would this layer cost on the paper's board?
    let planner = Planner::default();
    let layer = Layer::new(
        OpKind::Conv { k: 3, stride: 1, pad: 1, cout: 32, act: Activation::Relu },
        TensorShape::new(56, 56, 16),
    );
    let gpu = planner.gpu.cost(&layer);
    let fpga = planner.dhm.cost(&layer)?;
    let link = planner.link.transfer(layer.input.elems(), Precision::Int8);
    println!("\nsimulated platform costs for the same conv:");
    println!("  Jetson TX2 (CUDA):        {:.3} ms, {:.3} mJ", gpu.ms(), gpu.mj());
    println!("  Cyclone10GX (DHM):        {:.3} ms, {:.3} mJ", fpga.ms(), fpga.mj());
    println!("  PCIe xfer of its IFM:     {:.3} ms, {:.3} mJ", link.ms(), link.mj());
    println!(
        "  FPGA advantage:           {:.1}x energy, {:.1}x latency",
        gpu.joules / fpga.joules,
        gpu.seconds / fpga.seconds
    );

    // --- serving: one request through the batch-first engine
    let handle = EngineBuilder::new().model(ModelSpec::net("squeezenet")).build()?;
    let engine = handle.engine.clone();
    let shape = engine.input_shape("squeezenet").expect("registered");
    let resp = engine.infer(InferenceRequest::new("squeezenet", Tensor::randn(&shape, 0)))?;
    println!(
        "\nengine: squeezenet {:?} -> logits {:?} (batch {}, worker {})",
        shape, resp.output.shape, resp.batch_size, resp.worker
    );
    drop(engine);
    handle.shutdown();
    Ok(())
}
