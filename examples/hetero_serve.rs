//! Heterogeneous serving walkthrough: serve a model on the **online
//! device pipeline** (`ModelSpec::placement`) and watch the paper's
//! hybrid-beats-GPU-only claim with a stopwatch.
//!
//! The engine spins up one lane per device — FPGA, PCIe link, GPU — from
//! the model's partition plan; every lane bills the cost models' service
//! times against real (scaled) wall-clock, and bounded queues connect
//! them, so image i+1 is on the FPGA while image i is on the GPU. The
//! GPU-only placement is the same machinery with a single GPU lane, which
//! makes the wall-clock comparison apples-to-apples.
//!
//! Run: `cargo run --release --example hetero_serve [model] [images]`
//! (default: squeezenet, 32 images)

use hetero_dnn::coordinator::{Completion, EngineBuilder, InferenceRequest, ModelSpec};
use hetero_dnn::graph::models;
use hetero_dnn::hetero::stage_profile;
use hetero_dnn::partition::{Planner, Strategy};
use hetero_dnn::runtime::device::DEFAULT_TIME_SCALE;
use hetero_dnn::runtime::Tensor;
use std::sync::mpsc;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "squeezenet".into());
    let images: usize = std::env::args().nth(2).map(|s| s.parse()).transpose()?.unwrap_or(32);
    let Some(g) = models::by_name(&model, 224) else {
        anyhow::bail!("unknown model {model}");
    };

    // what the analytic pipeline model predicts for the two placements
    let planner = Planner::default();
    let base = stage_profile(&planner.plan_model(&g, Strategy::GpuOnly));
    let het = stage_profile(&planner.plan_model(&g, Strategy::Paper));
    println!("analytic steady-state period ({model}, time scale {DEFAULT_TIME_SCALE}):");
    println!(
        "  gpu-only : {:.3} ms/img (gpu {:.3})",
        base.bottleneck_seconds() * 1e3,
        base.gpu.seconds * 1e3
    );
    println!(
        "  hybrid   : {:.3} ms/img (gpu {:.3} | fpga {:.3} | link {:.3})",
        het.bottleneck_seconds() * 1e3,
        het.gpu.seconds * 1e3,
        het.fpga.seconds * 1e3,
        het.link.seconds * 1e3
    );

    // …and what the served pipeline actually does
    let mut measured: Vec<(&str, Duration)> = Vec::new();
    for (label, strat) in [("gpu-only", Strategy::GpuOnly), ("hybrid", Strategy::Paper)] {
        let handle = EngineBuilder::new()
            .max_wait(Duration::ZERO)
            .model(ModelSpec::net(&model).placement(strat))
            .build()?;
        let engine = handle.engine.clone();
        let shape = engine.input_shape(&model).expect("registered");
        let xs: Vec<Tensor> = (0..images as u64).map(|s| Tensor::randn(&shape, s)).collect();
        engine.infer(InferenceRequest::new(model.clone(), xs[0].clone()))?; // warm the lanes

        let (sink, done) = mpsc::channel::<Completion>();
        let t0 = Instant::now();
        let (mut submitted, mut received, mut in_flight) = (0usize, 0usize, 0usize);
        while received < images {
            while submitted < images && in_flight < 6 {
                let req = InferenceRequest::new(model.clone(), xs[submitted].clone());
                engine.submit(req, submitted as u64, &sink)?;
                submitted += 1;
                in_flight += 1;
            }
            let c = done.recv().expect("completion");
            c.result?;
            received += 1;
            in_flight -= 1;
        }
        let wall = t0.elapsed();
        println!(
            "served [{label:<8}] {images} images in {wall:?} — {:.0} img/s wall",
            images as f64 / wall.as_secs_f64()
        );
        if let Some(dm) = engine.device_metrics(&model) {
            let (bottleneck, busy) = dm.busiest();
            println!(
                "  lanes: gpu {:.1} ms sim, {:.2} J | fpga {:.1} ms, {:.2} J | link {:.1} ms, \
                 {:.2} MB crossed | bottleneck {bottleneck} ({:.1} ms total)",
                dm.gpu.sim_busy().as_secs_f64() * 1e3,
                dm.gpu.joules(),
                dm.fpga.sim_busy().as_secs_f64() * 1e3,
                dm.fpga.joules(),
                dm.link.sim_busy().as_secs_f64() * 1e3,
                dm.transferred_bytes() as f64 / 1e6,
                busy.as_secs_f64() * 1e3
            );
        }
        measured.push((label, wall));
        drop(engine);
        handle.shutdown();
    }

    if let [(_, gpu_only), (_, hybrid)] = measured[..] {
        let gain = gpu_only.as_secs_f64() / hybrid.as_secs_f64();
        println!(
            "hybrid-pipelined serving is {gain:.2}x GPU-only throughput \
             (analytic prediction {:.2}x) — the paper's claim, measured at the serving layer",
            base.bottleneck_seconds() / het.bottleneck_seconds()
        );
    }
    Ok(())
}
