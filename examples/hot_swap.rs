//! Live model hot-swap walkthrough (README §Operating the Engine).
//!
//! Demonstrates the three serving scenarios on one running engine:
//!
//! 1. start with squeezenet (result cache on, in-flight budget set),
//! 2. keep a client hammering it the whole time,
//! 3. `Engine::register` shufflenetv2_05 on the LIVE engine and serve it,
//! 4. `Engine::retire` it again — draining only its own pool,
//! 5. verify the squeezenet client never saw a single failure.
//!
//! Works in a fresh checkout: without built AOT artifacts the workers
//! fall back to the simulated platform runtime (announced on stderr).
//!
//! Run: `cargo run --release --example hot_swap`

use hetero_dnn::coordinator::{EngineBuilder, InferenceRequest, ModelSpec};
use hetero_dnn::runtime::Tensor;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    // 1. one model up front: cache 64 repeated inputs, cap 32 in flight
    let handle = EngineBuilder::new()
        .max_batch(8)
        .max_wait(Duration::from_micros(500))
        .model(ModelSpec::net("squeezenet").workers(2).cache(64).budget(32))
        .build()?;
    let engine = handle.engine.clone();
    println!("engine up: {:?}", engine.models());

    // 2. background client: sustained squeezenet traffic for the whole demo
    let stop = Arc::new(AtomicBool::new(false));
    let traffic = {
        let engine = engine.clone();
        let stop = stop.clone();
        std::thread::spawn(move || -> (u64, u64) {
            let shape = engine.input_shape("squeezenet").expect("registered");
            let (mut ok, mut failed) = (0u64, 0u64);
            let mut i = 0u64;
            while !stop.load(Ordering::Relaxed) {
                // cycle 16 distinct inputs so the result cache earns hits
                let x = Tensor::randn(&shape, i % 16);
                match engine.infer(InferenceRequest::new("squeezenet", x)) {
                    Ok(_) => ok += 1,
                    Err(_) => failed += 1,
                }
                i += 1;
            }
            (ok, failed)
        })
    };

    // 3. hot-swap IN: shufflenetv2_05 joins the live engine
    engine.register(ModelSpec::net("shufflenetv2_05").workers(2))?;
    println!("registered shufflenetv2_05: {:?}", engine.models());
    let shape = engine.input_shape("shufflenetv2_05").expect("registered");
    for seed in 0..4 {
        let resp = engine.infer(InferenceRequest::new(
            "shufflenetv2_05",
            Tensor::randn(&shape, seed),
        ))?;
        println!(
            "  shufflenetv2_05 seed {seed}: logits {:?} (batch {}, worker {})",
            resp.output.shape, resp.batch_size, resp.worker
        );
    }

    // 4. hot-swap OUT: drain only shufflenet's pool; squeezenet keeps going
    engine.retire("shufflenetv2_05")?;
    println!("retired shufflenetv2_05: {:?}", engine.models());
    assert!(
        engine
            .infer(InferenceRequest::new("shufflenetv2_05", Tensor::zeros(&[1, 224, 224, 3])))
            .is_err(),
        "a retired model must be unknown"
    );

    // 5. the sibling model never noticed
    std::thread::sleep(Duration::from_millis(50));
    stop.store(true, Ordering::Relaxed);
    let (ok, failed) = traffic.join().expect("traffic client");
    let metrics = engine.metrics("squeezenet").expect("registered");
    {
        let m = metrics.lock().unwrap();
        println!(
            "squeezenet during the swap: {ok} ok, {failed} failed | cache {}/{} hit ({:.0}%)",
            m.cache_hits,
            m.cache_hits + m.cache_misses,
            m.cache_hit_rate() * 100.0
        );
    }
    assert_eq!(failed, 0, "hot-swap must not disturb sibling traffic");

    drop(engine);
    handle.shutdown();
    println!("clean shutdown");
    Ok(())
}
