//! Heterogeneous full-network inference — the paper's core experiment as
//! one runnable program.
//!
//! For each of the three mobile CNNs this example:
//!   1. executes the 224x224 network end-to-end through the artifact
//!      runtime (finite class logits out of the full input->logits path),
//!   2. verifies one module's partition algebra numerically (Fig 2:
//!      split == monolith through actual artifacts),
//!   3. plans the network on the simulated FPGA+GPU board under the
//!      paper's strategy and prints the per-module timeline + totals vs
//!      the GPU-only baseline.
//!
//! Without built artifacts the simulated platform runtime steps in
//! (structural demo; the numeric equivalence checks only mean something
//! against real artifacts).
//!
//! Finally the same three nets are registered in **one multi-model
//! [`Engine`]** and served through the typed request API — the serving
//! face the paper's amortization argument leads to.
//!
//! Run: `cargo run --release --example hetero_inference`

use hetero_dnn::coordinator::{EngineBuilder, InferenceRequest, ModelSpec, Priority};
use hetero_dnn::graph::models;
use hetero_dnn::metrics::Gain;
use hetero_dnn::partition::{Planner, Strategy};
use hetero_dnn::runtime::{Runtime, Tensor};
use hetero_dnn::sched::{self, IdleParams};

fn main() -> anyhow::Result<()> {
    let rt = Runtime::new_or_simulated();
    let planner = Planner::default();

    // --- 2. partition algebra through real artifacts (Fire module)
    println!("== partition algebra check (Fire, Fig 2b) ==");
    let full = rt.load("fire_full")?;
    let gpu = rt.load("fire_gpu")?;
    let fpga = rt.load("fire_fpga_f32")?;
    let inputs = rt.synth_inputs("fire_full", 42)?;
    let want = &full.run(&inputs)?[0];
    let parts = gpu.run(&inputs[..3])?;
    let b = &fpga.run(&[parts[0].clone(), inputs[3].clone()])?[0];
    let got = parts[1].concat_last(b);
    println!("  max |split - monolith| = {:.2e}\n", got.max_abs_diff(want));

    for (artifact, model) in [
        ("squeezenet_224", "squeezenet"),
        ("mobilenetv2_05_224", "mobilenetv2_05"),
        ("shufflenetv2_05_224", "shufflenetv2_05"),
    ] {
        // --- 1. real end-to-end inference
        let exe = rt.load(artifact)?;
        let net_inputs = rt.synth_inputs(artifact, 7)?;
        let t0 = std::time::Instant::now();
        let logits = &exe.run(&net_inputs)?[0];
        let wall = t0.elapsed();
        let (argmax, _) = logits
            .data
            .iter()
            .enumerate()
            .fold((0, f32::MIN), |(bi, bv), (i, &v)| if v > bv { (i, v) } else { (bi, bv) });
        println!("== {model} ==");
        println!(
            "  end-to-end [{}]: {:?} -> argmax class {argmax} ({:?} wall)",
            rt.platform(),
            logits.shape,
            wall
        );

        // --- 3. simulated platform comparison
        let g = match model {
            "squeezenet" => models::squeezenet(224),
            "mobilenetv2_05" => models::mobilenetv2_05(224),
            _ => models::shufflenetv2_05(224),
        };
        let base_plan = planner.plan_model(&g, Strategy::GpuOnly);
        let het_plan = planner.plan_model_paper(&g);
        let base = sched::evaluate_model_with(&base_plan, IdleParams::paper());
        let het = sched::evaluate_model_with(&het_plan, IdleParams::paper());
        let gain = Gain::of(base.total, het.total);
        println!(
            "  GPU-only:  {:.3} ms  {:.3} mJ   ({} modules)",
            base.total.ms(),
            base.total.mj(),
            base.per_module.len()
        );
        println!(
            "  hetero:    {:.3} ms  {:.3} mJ   ({} on FPGA)",
            het.total.ms(),
            het.total.mj(),
            het_plan.modules.iter().filter(|m| m.uses_fpga).count()
        );
        println!(
            "  gain:      energy {:.2}x ({:.0}% reduction), latency {:.2}x ({:.0}% reduction)",
            gain.energy_gain,
            gain.energy_reduction_pct(),
            gain.latency_speedup,
            gain.latency_reduction_pct()
        );
        // the three most-improved modules
        let mut deltas: Vec<_> = base
            .per_module
            .iter()
            .zip(&het.per_module)
            .map(|((n, b), (_, h))| (n.clone(), b.joules - h.joules))
            .collect();
        deltas.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        println!("  top module savings:");
        for (name, dj) in deltas.iter().take(3) {
            println!("    {name:<10} {:.3} mJ", dj * 1e3);
        }
        println!();
    }

    // --- 4. all three nets behind one multi-model engine
    println!("== multi-model engine (one pool per net, shared front door) ==");
    let handle = EngineBuilder::new()
        .model(ModelSpec::net("squeezenet").workers(2))
        .model(ModelSpec::net("mobilenetv2_05").workers(2))
        .model(ModelSpec::net("shufflenetv2_05").workers(2))
        .build()?;
    let engine = handle.engine.clone();
    for model in ["squeezenet", "mobilenetv2_05", "shufflenetv2_05"] {
        let shape = engine.input_shape(model).expect("registered");
        let resp = engine.infer(
            InferenceRequest::new(model, Tensor::randn(&shape, 1)).with_priority(Priority::High),
        )?;
        println!(
            "  {model:<18} logits {:?} exec {:?} (batch {}, worker {})",
            resp.output.shape, resp.exec, resp.batch_size, resp.worker
        );
    }
    drop(engine);
    handle.shutdown();
    Ok(())
}
