//! Partition-strategy explorer: sweep every strategy over every module of
//! a network, print the cost matrix, and compare the paper's fixed mapping
//! against the Auto planner and the shared-fabric deployment plan.
//!
//! This is the design-space view motivating the paper's §IV choices: for
//! each module kind, one strategy dominates, and the resource cliff
//! decides where partitioning stops.
//!
//! Run: `cargo run --release --example partition_explorer [model]`

use hetero_dnn::graph::models;
use hetero_dnn::metrics::Report;
use hetero_dnn::partition::{Planner, Strategy};
use hetero_dnn::sched::{self, IdleParams};

fn main() -> anyhow::Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "shufflenetv2_05".into());
    let g = match model.as_str() {
        "squeezenet" => models::squeezenet(224),
        "mobilenetv2_05" => models::mobilenetv2_05(224),
        "shufflenetv2_05" => models::shufflenetv2_05(224),
        other => anyhow::bail!("unknown model {other}"),
    };
    let planner = Planner::default();

    let mut columns = vec!["module", "kind"];
    columns.extend(Strategy::MODULE_LEVEL.iter().map(Strategy::name));
    let mut r = Report::new(
        &format!("Strategy cost matrix — {} at 224 (ms / mJ per module)", g.name),
        &columns,
    );
    for m in &g.modules {
        let mut row = vec![m.name.clone(), format!("{:?}", m.kind)];
        for strat in Strategy::MODULE_LEVEL {
            row.push(match planner.plan_module(m, strat) {
                Ok(p) => {
                    let c = sched::evaluate_with(&p, IdleParams::paper()).total;
                    format!("{:.2}/{:.2}", c.ms(), c.mj())
                }
                Err(_) => "-".into(),
            });
        }
        r.row(row);
    }
    println!("{}", r.to_text());

    // whole-net comparison: baseline vs paper mapping vs auto vs deployable
    println!("whole-network totals:");
    let base = sched::evaluate_model_with(&planner.plan_model(&g, Strategy::GpuOnly), IdleParams::paper());
    println!("  gpu-only           : {:.3} ms  {:.3} mJ", base.total.ms(), base.total.mj());
    let paper = sched::evaluate_model_with(&planner.plan_model_paper(&g), IdleParams::paper());
    println!("  paper mapping      : {:.3} ms  {:.3} mJ", paper.total.ms(), paper.total.mj());
    let auto_plan = planner.plan_model(&g, Strategy::Auto);
    let auto = sched::evaluate_model(&auto_plan);
    let usage = auto_plan.fpga_usage();
    println!(
        "  auto (shared fabric): {:.3} ms  {:.3} mJ   [resident set: {} ALMs, {} M20K]",
        auto.total.ms(),
        auto.total.mj(),
        usage.alms,
        usage.m20ks
    );

    // where does the resource cliff bite?
    println!("\nresource cliff (modules denied a heterogeneous plan):");
    let het = planner.plan_model_paper(&g);
    for (m, p) in g.modules.iter().zip(&het.modules) {
        let applicable = Planner::paper_strategy(m.kind) != Strategy::GpuOnly;
        if applicable && !p.uses_fpga {
            println!("  {:<10} {:?} (IFM {}x{}x{})", m.name, m.kind, m.input.h, m.input.w, m.input.c);
        }
    }
    Ok(())
}
