//! Batch-pipelined throughput demo — the deployment-facing view.
//!
//! The paper optimizes single-image latency/energy; a deployed camera
//! pipeline cares about steady-state images/second. This example runs the
//! pipelined evaluator over growing batches, shows which engine bounds
//! throughput, and contrasts the paper-methodology plan with the
//! shared-fabric deployable plan.
//!
//! Run: `cargo run --release --example pipeline_throughput [model]`

use hetero_dnn::graph::models;
use hetero_dnn::partition::{Planner, Strategy};
use hetero_dnn::sched::{pipeline, IdleParams};

fn main() -> anyhow::Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "shufflenetv2_05".into());
    let g = match model.as_str() {
        "squeezenet" => models::squeezenet(224),
        "mobilenetv2_05" => models::mobilenetv2_05(224),
        "shufflenetv2_05" => models::shufflenetv2_05(224),
        other => anyhow::bail!("unknown model {other}"),
    };
    let planner = Planner::default();

    let plans = [
        ("gpu-only", planner.plan_model(&g, Strategy::GpuOnly)),
        ("paper hetero", planner.plan_model_paper(&g)),
        ("deployable (shared fabric)", planner.plan_model(&g, Strategy::Auto)),
    ];

    println!("pipelined throughput — {} at 224x224\n", g.name);
    println!("{:<28} {:>6} {:>12} {:>12} {:>14} {:>10}", "plan", "batch", "img/s", "ms/img", "mJ/img", "bottleneck");
    for (name, plan) in &plans {
        for n in [1usize, 2, 8, 32, 128] {
            let run = pipeline::evaluate_pipeline(plan, n, IdleParams::default());
            println!(
                "{:<28} {:>6} {:>12.1} {:>12.3} {:>14.3} {:>10}",
                name,
                n,
                run.throughput,
                run.makespan / n as f64 * 1e3,
                run.joules_per_image() * 1e3,
                format!("{:?}", run.bottleneck),
            );
        }
        println!();
    }

    let d_base = pipeline::service_demand(&plans[0].1);
    let d_het = pipeline::service_demand(&plans[1].1);
    println!("service demand per image (GPU / FPGA / PCIe, ms):");
    println!("  gpu-only : {:.3} / {:.3} / {:.3}", d_base.gpu * 1e3, d_base.fpga * 1e3, d_base.link * 1e3);
    println!("  hetero   : {:.3} / {:.3} / {:.3}", d_het.gpu * 1e3, d_het.fpga * 1e3, d_het.link * 1e3);
    println!("\nthe hetero plan moves {:.0}% of the per-image work off the GPU,",
        (1.0 - d_het.gpu / d_base.gpu) * 100.0);
    println!("which is exactly the steady-state throughput gain once pipelined.");
    Ok(())
}
