//! End-to-end serving demo — the E2E validation driver (DESIGN.md §5).
//!
//! Builds a multi-model [`Engine`] (one deadline batcher + executor pool
//! per model, batch-first execution, shared front door), pushes
//! classification requests for **two models concurrently** from parallel
//! clients, and reports per-model latency/throughput next to the
//! simulated FPGA+GPU platform cost. When the AOT artifacts are not
//! built the workers fall back to the simulated platform runtime
//! (announced on stderr), so this demo runs end-to-end in a fresh
//! checkout / CI. Recorded in EXPERIMENTS.md §E2E.
//!
//! With `--tcp`, the engine serves behind the TCP front end and every
//! client drives it over wire protocol v2 with a pipelined
//! [`AsyncClient`] — up to `--pipeline N` requests in flight per
//! connection (default 8), responses matched by id in completion order
//! (PROTOCOL.md).
//!
//! Run: `cargo run --release --example serve -- [requests] [clients] [workers] [--tcp] [--pipeline N]`

use hetero_dnn::coordinator::protocol::{AsyncClient, Reply};
use hetero_dnn::coordinator::server::Server;
use hetero_dnn::coordinator::{Engine, EngineBuilder, InferenceRequest, ModelSpec};
use hetero_dnn::partition::Strategy;
use hetero_dnn::runtime::Tensor;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut positional: Vec<usize> = Vec::new();
    let mut tcp = false;
    let mut pipeline = 8usize;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--tcp" => tcp = true,
            "--pipeline" => {
                pipeline = it
                    .next()
                    .and_then(|v| v.parse().ok())
                    .ok_or_else(|| anyhow::anyhow!("--pipeline needs a positive integer"))?;
                anyhow::ensure!(pipeline > 0, "--pipeline must be >= 1");
            }
            other => positional.push(
                other
                    .parse()
                    .map_err(|_| anyhow::anyhow!("unexpected argument {other:?}"))?,
            ),
        }
    }
    let requests: usize = positional.first().copied().unwrap_or(24);
    let clients: usize = positional.get(1).copied().unwrap_or(4).max(1);
    let workers: usize = positional.get(2).copied().unwrap_or(2);

    let handle = EngineBuilder::new()
        .max_batch(8)
        .max_wait(Duration::from_millis(2))
        .model(ModelSpec::net("squeezenet").workers(workers))
        .model(ModelSpec::net("shufflenetv2_05").workers(workers))
        .build()?;
    let engine = handle.engine.clone();
    let names: Vec<String> = engine.models();
    println!(
        "engine up: [{}] ({} requests, {} clients, {} workers per model{})",
        names.join(", "),
        requests,
        clients,
        workers,
        if tcp { ", wire v2 pipelined over TCP" } else { "" }
    );

    let t0 = std::time::Instant::now();
    if tcp {
        run_tcp_clients(&engine, &names, requests, clients, pipeline)?;
    } else {
        run_inprocess_clients(&engine, &names, requests, clients);
    }
    let wall = t0.elapsed();

    println!("\n== measured (batch-first engine, wall clock) ==");
    let mut total = 0u64;
    for name in &names {
        let metrics = engine.metrics(name).expect("registered");
        let m = metrics.lock().unwrap();
        total += m.served;
        println!(
            "  {name:<18} served {:>4} | exec mean {:.2} ms | p50/p99 {:.1}/{:.1} ms | mean batch {:.2}",
            m.served,
            m.exec_us_total as f64 / m.served.max(1) as f64 / 1e3,
            m.percentile(0.5) as f64 / 1e3,
            m.percentile(0.99) as f64 / 1e3,
            m.mean_batch()
        );
    }
    println!(
        "  total             : {} requests in {:.2?} ({:.2} req/s)",
        total,
        wall,
        total as f64 / wall.as_secs_f64()
    );

    // simulated platform verdict for the served models
    let planner = hetero_dnn::partition::Planner::default();
    println!("\n== simulated embedded platform (per request) ==");
    for (name, g) in [
        ("squeezenet", hetero_dnn::graph::squeezenet(224)),
        ("shufflenetv2_05", hetero_dnn::graph::shufflenetv2_05(224)),
    ] {
        let base = hetero_dnn::sched::evaluate_model_with(
            &planner.plan_model(&g, Strategy::GpuOnly),
            hetero_dnn::sched::IdleParams::paper(),
        )
        .total;
        let het = hetero_dnn::sched::evaluate_model_with(
            &planner.plan_model_paper(&g),
            hetero_dnn::sched::IdleParams::paper(),
        )
        .total;
        println!(
            "  {name:<18} GPU-only {:.3} ms / {:.3} mJ -> FPGA+GPU {:.3} ms / {:.3} mJ (energy {:.2}x)",
            base.ms(),
            base.mj(),
            het.ms(),
            het.mj(),
            base.joules / het.joules
        );
    }

    drop(engine);
    handle.shutdown();
    Ok(())
}

/// In-process driver: each client thread calls the blocking
/// [`Engine::infer`] front door directly.
fn run_inprocess_clients(engine: &Engine, names: &[String], requests: usize, clients: usize) {
    let mut joins = Vec::new();
    for c in 0..clients {
        let engine = engine.clone();
        let names = names.to_vec();
        let n = requests / clients + usize::from(c < requests % clients);
        joins.push(std::thread::spawn(move || {
            for i in 0..n {
                // interleave the two models on every client connection
                let model = names[(c + i) % names.len()].clone();
                let shape = engine.input_shape(&model).expect("registered");
                let x = Tensor::randn(&shape, (c * 7919 + i) as u64);
                let resp = engine.infer(InferenceRequest::new(model, x)).expect("infer");
                assert_eq!(resp.output.shape, vec![1, 1000]);
            }
        }));
    }
    for j in joins {
        j.join().expect("client");
    }
}

/// TCP driver: the engine serves behind [`Server`]; each client keeps up
/// to `depth` requests in flight on ONE v2 connection and matches the
/// completion-order responses back to its submissions by id.
fn run_tcp_clients(
    engine: &Engine,
    names: &[String],
    requests: usize,
    clients: usize,
    depth: usize,
) -> anyhow::Result<()> {
    let server = Server::start("127.0.0.1:0", engine.clone())?;
    println!("wire v2 server on {} (pipeline depth {depth})", server.addr);
    let mut joins = Vec::new();
    for c in 0..clients {
        let addr = server.addr;
        let names = names.to_vec();
        let n = requests / clients + usize::from(c < requests % clients);
        joins.push(std::thread::spawn(move || -> anyhow::Result<()> {
            let mut client = AsyncClient::connect(&addr)?;
            let mut pending = std::collections::HashSet::new();
            let (mut submitted, mut received) = (0usize, 0usize);
            while received < n {
                // keep the pipeline full before draining a completion
                while submitted < n && client.in_flight() < depth {
                    let model = &names[(c + submitted) % names.len()];
                    let shape = client
                        .models()
                        .iter()
                        .find(|(m, _)| m == model)
                        .map(|(_, s)| s.clone())
                        .ok_or_else(|| anyhow::anyhow!("model {model} not in HELLO_ACK table"))?;
                    let x = Tensor::randn(&shape, (c * 7919 + submitted) as u64);
                    let id = client.submit(Some(model.as_str()), &x)?;
                    pending.insert(id);
                    submitted += 1;
                }
                match client.recv()? {
                    Reply::Response(r) => {
                        anyhow::ensure!(
                            pending.remove(&r.id),
                            "response id {} matches no in-flight submit",
                            r.id
                        );
                        anyhow::ensure!(r.output.shape == vec![1, 1000], "bad output shape");
                        received += 1;
                    }
                    Reply::Error { id, code, message, .. } => {
                        anyhow::bail!("request {id} failed: {code}: {message}")
                    }
                }
            }
            Ok(())
        }));
    }
    for j in joins {
        j.join().expect("client thread")?;
    }
    server.stop();
    Ok(())
}
