//! End-to-end serving demo — the E2E validation driver (DESIGN.md §5).
//!
//! Starts the coordinator (deadline batcher + N-worker executor pool),
//! pushes batched classification requests from concurrent clients, and
//! reports measured latency/throughput next to the simulated FPGA+GPU
//! platform cost per request. When the AOT artifacts are not built the
//! workers fall back to the simulated platform runtime (announced on
//! stderr), so this demo runs end-to-end in a fresh checkout / CI.
//! Recorded in EXPERIMENTS.md §E2E.
//!
//! Run: `cargo run --release --example serve -- [requests] [clients] [workers]`

use hetero_dnn::coordinator::{Coordinator, CoordinatorConfig};
use hetero_dnn::partition::Strategy;
use hetero_dnn::runtime::Tensor;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let requests: usize = args.get(1).and_then(|v| v.parse().ok()).unwrap_or(24);
    let clients: usize = args.get(2).and_then(|v| v.parse().ok()).unwrap_or(4);
    let workers: usize = args.get(3).and_then(|v| v.parse().ok()).unwrap_or(2);

    let cfg = CoordinatorConfig {
        artifact: "squeezenet_224".into(),
        model: "squeezenet".into(),
        strategy: Strategy::Auto,
        max_batch: 8,
        max_wait: Duration::from_millis(2),
        seed: 0,
        admission: None,
        workers,
    };
    println!(
        "starting coordinator for {} ({} requests, {} clients, {} workers)",
        cfg.artifact, requests, clients, workers
    );
    let handle = Coordinator::start(cfg)?;
    let coord = handle.coordinator.clone();
    let shape = coord.input_shape().to_vec();

    let t0 = std::time::Instant::now();
    let mut joins = Vec::new();
    for c in 0..clients {
        let coord = coord.clone();
        let shape = shape.clone();
        let n = requests / clients + usize::from(c < requests % clients);
        joins.push(std::thread::spawn(move || {
            for i in 0..n {
                let x = Tensor::randn(&shape, (c * 7919 + i) as u64);
                let resp = coord.infer(x).expect("infer");
                assert_eq!(resp.output.shape, vec![1, 1000]);
            }
        }));
    }
    for j in joins {
        j.join().expect("client");
    }
    let wall = t0.elapsed();

    let m = coord.metrics.lock().unwrap();
    println!("\n== measured (executor pool, wall clock) ==");
    println!("  served            : {} requests in {:.2?}", m.served, wall);
    println!("  throughput        : {:.2} req/s", m.served as f64 / wall.as_secs_f64());
    println!("  exec mean         : {:.1} ms", m.exec_us_total as f64 / m.served.max(1) as f64 / 1e3);
    println!("  latency p50 / p99 : {:.1} / {:.1} ms",
             m.percentile(0.5) as f64 / 1e3, m.percentile(0.99) as f64 / 1e3);
    println!("  mean batch size   : {:.2}", m.mean_batch());
    drop(m);

    // simulated platform verdict for the served model
    let planner = hetero_dnn::partition::Planner::default();
    let g = hetero_dnn::graph::squeezenet(224);
    let base = hetero_dnn::sched::evaluate_model_with(
        &planner.plan_model(&g, Strategy::GpuOnly),
        hetero_dnn::sched::IdleParams::paper(),
    )
    .total;
    let het = hetero_dnn::sched::evaluate_model_with(
        &planner.plan_model_paper(&g),
        hetero_dnn::sched::IdleParams::paper(),
    )
    .total;
    println!("\n== simulated embedded platform (per request) ==");
    println!("  GPU-only   : {:.3} ms  {:.3} mJ", base.ms(), base.mj());
    println!("  FPGA+GPU   : {:.3} ms  {:.3} mJ", het.ms(), het.mj());
    println!("  energy gain: {:.2}x   latency speedup: {:.2}x",
             base.joules / het.joules, base.seconds / het.seconds);

    drop(coord);
    handle.shutdown();
    Ok(())
}
