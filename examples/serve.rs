//! End-to-end serving demo — the E2E validation driver (DESIGN.md §5).
//!
//! Builds a multi-model [`Engine`] (one deadline batcher + executor pool
//! per model, batch-first execution, shared front door), pushes
//! classification requests for **two models concurrently** from parallel
//! clients, and reports per-model latency/throughput next to the
//! simulated FPGA+GPU platform cost. When the AOT artifacts are not
//! built the workers fall back to the simulated platform runtime
//! (announced on stderr), so this demo runs end-to-end in a fresh
//! checkout / CI. Recorded in EXPERIMENTS.md §E2E.
//!
//! Run: `cargo run --release --example serve -- [requests] [clients] [workers]`

use hetero_dnn::coordinator::{EngineBuilder, InferenceRequest, ModelSpec};
use hetero_dnn::partition::Strategy;
use hetero_dnn::runtime::Tensor;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let requests: usize = args.get(1).and_then(|v| v.parse().ok()).unwrap_or(24);
    let clients: usize = args.get(2).and_then(|v| v.parse().ok()).unwrap_or(4);
    let workers: usize = args.get(3).and_then(|v| v.parse().ok()).unwrap_or(2);

    let handle = EngineBuilder::new()
        .max_batch(8)
        .max_wait(Duration::from_millis(2))
        .model(ModelSpec::net("squeezenet").workers(workers))
        .model(ModelSpec::net("shufflenetv2_05").workers(workers))
        .build()?;
    let engine = handle.engine.clone();
    let names: Vec<String> = engine.models();
    println!(
        "engine up: [{}] ({} requests, {} clients, {} workers per model)",
        names.join(", "),
        requests,
        clients,
        workers
    );

    let t0 = std::time::Instant::now();
    let mut joins = Vec::new();
    for c in 0..clients {
        let engine = engine.clone();
        let names = names.clone();
        let n = requests / clients + usize::from(c < requests % clients);
        joins.push(std::thread::spawn(move || {
            for i in 0..n {
                // interleave the two models on every client connection
                let model = names[(c + i) % names.len()].clone();
                let shape = engine.input_shape(&model).expect("registered");
                let x = Tensor::randn(&shape, (c * 7919 + i) as u64);
                let resp = engine.infer(InferenceRequest::new(model, x)).expect("infer");
                assert_eq!(resp.output.shape, vec![1, 1000]);
            }
        }));
    }
    for j in joins {
        j.join().expect("client");
    }
    let wall = t0.elapsed();

    println!("\n== measured (batch-first engine, wall clock) ==");
    let mut total = 0u64;
    for name in &names {
        let metrics = engine.metrics(name).expect("registered");
        let m = metrics.lock().unwrap();
        total += m.served;
        println!(
            "  {name:<18} served {:>4} | exec mean {:.2} ms | p50/p99 {:.1}/{:.1} ms | mean batch {:.2}",
            m.served,
            m.exec_us_total as f64 / m.served.max(1) as f64 / 1e3,
            m.percentile(0.5) as f64 / 1e3,
            m.percentile(0.99) as f64 / 1e3,
            m.mean_batch()
        );
    }
    println!(
        "  total             : {} requests in {:.2?} ({:.2} req/s)",
        total,
        wall,
        total as f64 / wall.as_secs_f64()
    );

    // simulated platform verdict for the served models
    let planner = hetero_dnn::partition::Planner::default();
    println!("\n== simulated embedded platform (per request) ==");
    for (name, g) in [
        ("squeezenet", hetero_dnn::graph::squeezenet(224)),
        ("shufflenetv2_05", hetero_dnn::graph::shufflenetv2_05(224)),
    ] {
        let base = hetero_dnn::sched::evaluate_model_with(
            &planner.plan_model(&g, Strategy::GpuOnly),
            hetero_dnn::sched::IdleParams::paper(),
        )
        .total;
        let het = hetero_dnn::sched::evaluate_model_with(
            &planner.plan_model_paper(&g),
            hetero_dnn::sched::IdleParams::paper(),
        )
        .total;
        println!(
            "  {name:<18} GPU-only {:.3} ms / {:.3} mJ -> FPGA+GPU {:.3} ms / {:.3} mJ (energy {:.2}x)",
            base.ms(),
            base.mj(),
            het.ms(),
            het.mj(),
            base.joules / het.joules
        );
    }

    drop(engine);
    handle.shutdown();
    Ok(())
}
