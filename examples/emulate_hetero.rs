//! Functional emulation of the paper's heterogeneous execution.
//!
//! Runs SqueezeNet through the per-module artifact chain twice:
//!   1. monolithic (GPU-only dataflow),
//!   2. heterogeneous: every Fire module split per Fig 2b — the GPU
//!      artifact computes squeeze+expand1x1, the squeeze OFM crosses an
//!      int8 "PCIe boundary", the FPGA artifact computes expand3x3 in the
//!      8-bit DHM datapath, and the coordinator concatenates.
//!
//! It reports the logit drift and top-5 agreement between the two
//! dataflows — the functional proof behind the whole paper — and prices
//! each boundary crossing on the simulated PCIe link.
//!
//! Offline builds execute through the deterministic in-tree backend
//! (DESIGN.md §Backends), so this is a *structural* demo of the dataflow —
//! the drift numbers only become meaningful once a real kernel backend
//! lands. The banner names the active backend.
//!
//! Run: `cargo run --release --example emulate_hetero`

use hetero_dnn::link::{LinkModel, Precision};
use hetero_dnn::runtime::chain::{ChainExecutor, FpgaPrecision};
use hetero_dnn::runtime::{Runtime, Tensor};

fn top_k(t: &Tensor, k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..t.data.len()).collect();
    idx.sort_by(|&a, &b| t.data[b].partial_cmp(&t.data[a]).unwrap());
    idx.truncate(k);
    idx
}

fn main() -> anyhow::Result<()> {
    let rt = Runtime::new_or_simulated();
    let chain = ChainExecutor::new(&rt, 42)?;
    let x = Tensor::randn(&[1, 224, 224, 3], 7);

    println!("running SqueezeNet through the per-module artifact chain...");
    let t0 = std::time::Instant::now();
    let mono = chain.run_monolithic(&x)?;
    let t_mono = t0.elapsed();

    let t0 = std::time::Instant::now();
    let het_f32 = chain.run_hetero(&x, FpgaPrecision::F32)?;
    let t_f32 = t0.elapsed();

    let t0 = std::time::Instant::now();
    let het_q8 = chain.run_hetero(&x, FpgaPrecision::Int8)?;
    let t_q8 = t0.elapsed();

    println!("\n== functional results (backend: {}) ==", rt.platform());
    println!("  monolithic        : {:?} wall", t_mono);
    println!("  hetero (f32 link) : {:?} wall, max|diff| = {:.2e}", t_f32, het_f32.max_abs_diff(&mono));
    println!("  hetero (int8 DHM) : {:?} wall, rel err  = {:.4}", t_q8, het_q8.rel_error(&mono));

    let m5 = top_k(&mono, 5);
    let q5 = top_k(&het_q8, 5);
    let overlap = m5.iter().filter(|c| q5.contains(c)).count();
    println!("  top-5 (monolithic): {m5:?}");
    println!("  top-5 (int8 path) : {q5:?}  ({overlap}/5 agree, top-1 {})",
             if m5[0] == q5[0] { "PRESERVED" } else { "FLIPPED" });

    // what each boundary crossing costs on the paper's link
    println!("\n== simulated PCIe boundary costs (per Fire module) ==");
    let link = LinkModel::default();
    for (name, h, s_ch, e3_ch) in [
        ("fire2", 54usize, 16usize, 64usize),
        ("fire5", 26, 32, 128),
        ("fire9", 12, 64, 256),
    ] {
        let to_fpga = link.transfer(h * h * s_ch, Precision::Int8);
        let back = link.transfer(h * h * e3_ch, Precision::Int8);
        println!(
            "  {name:<6} {0}x{0}: squeeze->FPGA {1:.1} us, OFM->GPU {2:.1} us, {3:.1} uJ total",
            h,
            to_fpga.seconds * 1e6,
            back.seconds * 1e6,
            (to_fpga.joules + back.joules) * 1e6
        );
    }
    println!("\n(int8 features are what keep these crossings cheap — the paper's\n 8-bit fixed point is as much a link optimization as a compute one)");
    Ok(())
}
